//! The composed hierarchy: translation (optional) + caches + DRAM.

use crate::memsim::page_table::PageTable;
use crate::memsim::{
    Cache, HierarchyConfig, PageSize, Prefetcher, PtwCache, SimStats, Tlb,
};

/// Whether addresses are translated before the data access.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AddressMode {
    /// The paper's proposal: no translation, addresses go straight to
    /// the cache hierarchy. Zero translation cycles by construction.
    Physical,
    /// Traditional virtual memory at the given page size. Every access
    /// probes the DTLB; misses escalate to the STLB and then a page walk
    /// whose PTE loads go through the data caches.
    Virtual(PageSize),
}

/// A single-core memory hierarchy simulator.
///
/// `access` returns the *serialized* latency of one access: dependent
/// pointer chases (tree walks) should sum these; independent streaming
/// accesses overlap in a real OoO core, which the workload models account
/// for explicitly (see `workloads::trace`).
pub struct Hierarchy {
    cfg: HierarchyConfig,
    mode: AddressMode,
    l1: Cache,
    l2: Cache,
    l3: Cache,
    dtlb_4k: Tlb,
    dtlb_2m: Tlb,
    dtlb_1g: Tlb,
    stlb: Tlb,
    pwc: PtwCache,
    prefetcher: Prefetcher,
    stats: SimStats,
    pf_buf: Vec<u64>,
}

impl Hierarchy {
    /// Build a hierarchy in `mode` from `cfg`.
    pub fn new(cfg: HierarchyConfig, mode: AddressMode) -> Self {
        Hierarchy {
            l1: Cache::new(cfg.l1),
            l2: Cache::new(cfg.l2),
            l3: Cache::new(cfg.l3),
            dtlb_4k: Tlb::new(cfg.dtlb_4k),
            dtlb_2m: Tlb::new(cfg.dtlb_2m),
            dtlb_1g: Tlb::new(cfg.dtlb_1g),
            stlb: Tlb::new(cfg.stlb),
            pwc: PtwCache::new(cfg.pwc_entries),
            prefetcher: Prefetcher::new(cfg.prefetch_degree),
            cfg,
            mode,
            stats: SimStats::default(),
            pf_buf: Vec::with_capacity(4),
        }
    }

    /// Kaby Lake hierarchy in the given mode.
    pub fn kaby_lake(mode: AddressMode) -> Self {
        Self::new(HierarchyConfig::kaby_lake(), mode)
    }

    /// Address mode.
    pub fn mode(&self) -> AddressMode {
        self.mode
    }

    /// Simulate one data access; returns its serialized cycle cost
    /// (translation + data).
    #[inline]
    pub fn access(&mut self, addr: u64) -> u64 {
        let (t, d) = self.access_split(addr);
        t + d
    }

    /// Simulate one access, returning `(translation, data)` cycles
    /// separately. Workload cost models overlap the two components
    /// differently: page walks of independent accesses overlap with
    /// neighboring work (the paper's §4.2 observation that PTW caches
    /// and prefetchers "reduce the time to handle each TLB miss"),
    /// while dependent pointer chases serialize fully.
    #[inline]
    pub fn access_split(&mut self, addr: u64) -> (u64, u64) {
        let mut trans = 0u64;
        if let AddressMode::Virtual(page) = self.mode {
            trans = self.translate(addr, page);
            self.stats.translation_cycles += trans;
        }
        let data = self.data_access(addr, true);
        self.stats.accesses += 1;
        self.stats.cycles += trans + data;
        (trans, data)
    }

    /// TLB probe + (on miss) page walk. Returns translation cycles.
    #[inline]
    fn translate(&mut self, vaddr: u64, page: PageSize) -> u64 {
        let vpn = vaddr >> page.shift();
        let dtlb = match page {
            PageSize::P4K => &mut self.dtlb_4k,
            PageSize::P2M => &mut self.dtlb_2m,
            PageSize::P1G => &mut self.dtlb_1g,
        };
        if dtlb.lookup(vpn) {
            self.stats.dtlb_hits += 1;
            return 0; // folded into L1 pipeline
        }
        self.stats.dtlb_misses += 1;
        let mut cycles = self.cfg.stlb_latency;
        let stlb_eligible = page != PageSize::P1G || self.cfg.stlb_holds_1g;
        if stlb_eligible && self.stlb.lookup(vpn) {
            self.stats.stlb_hits += 1;
            let dtlb = match page {
                PageSize::P4K => &mut self.dtlb_4k,
                PageSize::P2M => &mut self.dtlb_2m,
                PageSize::P1G => &mut self.dtlb_1g,
            };
            dtlb.insert(vpn);
            return cycles;
        }
        // Page walk: skip levels via the PTW cache, then issue one PTE
        // load per remaining level through the data caches.
        self.stats.walks += 1;
        let skip = self.pwc.lookup(vaddr, page);
        let first = skip;
        for level in first..page.walk_levels() {
            let pte = PageTable::pte_addr(level, vaddr, page);
            cycles += self.data_access(pte, false);
            self.stats.walk_loads += 1;
        }
        self.pwc.insert(vaddr, page);
        let dtlb = match page {
            PageSize::P4K => &mut self.dtlb_4k,
            PageSize::P2M => &mut self.dtlb_2m,
            PageSize::P1G => &mut self.dtlb_1g,
        };
        dtlb.insert(vpn);
        if stlb_eligible {
            self.stlb.insert(vpn);
        }
        cycles
    }

    /// One access through L1→L2→L3→DRAM. `demand` distinguishes demand
    /// loads (train the prefetcher, counted in level stats) from PTE
    /// loads.
    #[inline]
    fn data_access(&mut self, addr: u64, demand: bool) -> u64 {
        // Prefetcher trains on all demand accesses (training on the
        // L1-miss stream only was tried and *cost* 25% wall time: the
        // late-confirmed streams produce more DRAM-path simulation work
        // than the observe() calls saved — EXPERIMENTS.md §Perf).
        if demand && self.cfg.prefetch_degree > 0 {
            let line = addr >> 6;
            // Split borrows: observe, then fill.
            let mut buf = std::mem::take(&mut self.pf_buf);
            self.prefetcher.observe(line, &mut buf);
            for &pl in &buf {
                let pa = pl << 6;
                // Prefetch into L2 (and L3): hides DRAM latency on
                // streams without polluting L1.
                self.l2.fill(pa);
                self.l3.fill(pa);
                self.stats.prefetches += 1;
            }
            self.pf_buf = buf;
        }
        if self.l1.access(addr) {
            if demand {
                self.stats.l1_hits += 1;
            }
            return self.l1.latency();
        }
        if self.l2.access(addr) {
            if demand {
                self.stats.l2_hits += 1;
            }
            return self.l2.latency();
        }
        if self.l3.access(addr) {
            if demand {
                self.stats.l3_hits += 1;
            }
            return self.l3.latency();
        }
        if demand {
            self.stats.dram_accesses += 1;
        } else {
            self.stats.walk_dram_loads += 1;
        }
        self.cfg.dram_latency
    }

    /// Statistics snapshot.
    pub fn stats(&self) -> SimStats {
        self.stats
    }

    /// Reset all state (caches, TLBs, stats) keeping the configuration.
    pub fn reset(&mut self) {
        self.l1.reset();
        self.l2.reset();
        self.l3.reset();
        self.dtlb_4k.reset();
        self.dtlb_2m.reset();
        self.dtlb_1g.reset();
        self.stlb.reset();
        self.pwc.reset();
        self.prefetcher.reset();
        self.stats = SimStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn phys() -> Hierarchy {
        Hierarchy::kaby_lake(AddressMode::Physical)
    }
    fn virt4k() -> Hierarchy {
        Hierarchy::kaby_lake(AddressMode::Virtual(PageSize::P4K))
    }

    #[test]
    fn physical_mode_never_translates() {
        let mut h = phys();
        for i in 0..10_000u64 {
            h.access(i * 4096); // one access per page
        }
        let s = h.stats();
        assert_eq!(s.translation_cycles, 0);
        assert_eq!(s.dtlb_misses, 0);
        assert_eq!(s.walks, 0);
    }

    #[test]
    fn l1_hit_costs_l1_latency() {
        let mut h = phys();
        h.access(0x100);
        let c = h.access(0x100);
        assert_eq!(c, 4);
    }

    #[test]
    fn cold_access_costs_dram() {
        let mut h = phys();
        let c = h.access(0xDEAD_0000);
        assert_eq!(c, 250);
    }

    #[test]
    fn virtual_mode_walks_on_cold_tlb() {
        let mut h = virt4k();
        let c = h.access(0x1234_5000);
        // Cold: STLB penalty + 4 PTE loads (cold = DRAM each) + data DRAM.
        assert!(c > 250, "cold virtual access too cheap: {c}");
        assert_eq!(h.stats().walks, 1);
        assert_eq!(h.stats().walk_loads, 4);
    }

    #[test]
    fn same_page_second_access_hits_tlb() {
        let mut h = virt4k();
        h.access(0x8000);
        let before = h.stats().dtlb_hits;
        h.access(0x8008);
        assert_eq!(h.stats().dtlb_hits, before + 1);
    }

    #[test]
    fn tlb_reach_exceeded_causes_misses() {
        // 64-entry 4K DTLB + 1536-entry STLB: 4096 pages round-robin
        // blows both.
        let mut h = virt4k();
        let pages = 4096u64;
        for round in 0..3 {
            for p in 0..pages {
                h.access(p * 4096);
            }
            if round == 0 {
                // ignore cold effects
            }
        }
        let s = h.stats();
        assert!(
            s.dtlb_misses as f64 / (s.dtlb_hits + s.dtlb_misses) as f64 > 0.9,
            "expected >90% DTLB miss rate, got {:.3}",
            s.tlb_miss_rate()
        );
    }

    #[test]
    fn sequential_scan_translation_is_cheap() {
        // The paper's observation: linear scans suffer little from
        // translation because PTEs share lines + PWC skips levels.
        let mut h = virt4k();
        let n = 1 << 22; // 4M sequential bytes
        let mut total = 0u64;
        for addr in (0..n as u64).step_by(64) {
            total += h.access(addr);
        }
        let s = h.stats();
        let share = s.translation_cycles as f64 / total as f64;
        assert!(share < 0.10, "translation share {share:.3} too high for sequential");
    }

    #[test]
    fn physical_beats_virtual_on_random_large() {
        let mut hv = virt4k();
        let mut hp = phys();
        let mut rng = crate::testutil::Rng::new(1);
        let span = 4u64 << 30; // 4 GB address space
        let mut cv = 0u64;
        let mut cp = 0u64;
        for _ in 0..200_000 {
            let a = rng.below(span) & !3;
            cv += hv.access(a);
            cp += hp.access(a);
        }
        assert!(
            cv as f64 > cp as f64 * 1.2,
            "virtual ({cv}) should cost >1.2x physical ({cp}) on random 4 GB"
        );
    }

    #[test]
    fn huge_pages_fix_medium_random() {
        // 2 GB random working set: 4 KB pages thrash the TLB, 1 GB pages
        // fit in the 4-entry 1G DTLB.
        let span = 2u64 << 30;
        let mut h4k = virt4k();
        let mut h1g = Hierarchy::kaby_lake(AddressMode::Virtual(PageSize::P1G));
        let mut rng = crate::testutil::Rng::new(2);
        let mut c4 = 0u64;
        let mut c1 = 0u64;
        for _ in 0..100_000 {
            let a = rng.below(span) & !3;
            c4 += h4k.access(a);
            c1 += h1g.access(a);
        }
        assert!(c4 > c1, "4K pages ({c4}) should cost more than 1G pages ({c1})");
        assert!(h1g.stats().tlb_miss_rate() < 0.01);
    }

    #[test]
    fn huge_page_artifact_beyond_dtlb_reach() {
        // The paper's §4.3 artifact: >4 GB working sets on 1 GB pages
        // start missing the 4-entry 1G DTLB (and Kaby Lake's STLB holds
        // no 1 GB entries), so "physical via huge pages" stops being
        // faithful. Our model reproduces that.
        let span = 32u64 << 30;
        let mut h1g = Hierarchy::kaby_lake(AddressMode::Virtual(PageSize::P1G));
        let mut rng = crate::testutil::Rng::new(3);
        for _ in 0..100_000 {
            h1g.access(rng.below(span) & !3);
        }
        assert!(
            h1g.stats().tlb_miss_rate() > 0.5,
            "expected heavy 1G TLB misses at 32 GB, got {:.3}",
            h1g.stats().tlb_miss_rate()
        );
    }

    #[test]
    fn reset_clears_everything() {
        let mut h = virt4k();
        h.access(0x1000);
        h.reset();
        let s = h.stats();
        assert_eq!(s.accesses, 0);
        assert_eq!(s.cycles, 0);
    }
}
