//! Aggregated simulation statistics, plus the energy model behind the
//! paper's §2 claim that *"current translation infrastructure uses as
//! much space as an L1 cache and up to 15% of a chip's energy"*.

/// Per-event energy constants in picojoules, order-of-magnitude values
/// from published CACTI-style estimates for a ~14 nm core (the paper's
/// i7-7700 generation). Only *relative* magnitudes matter for the
/// translation-share experiment.
#[derive(Clone, Copy, Debug)]
pub struct EnergyModel {
    /// One DTLB lookup (CAM/SRAM probe, paid on every virtual access).
    pub tlb_lookup_pj: f64,
    /// One STLB probe.
    pub stlb_lookup_pj: f64,
    /// One page-walk PTE load issued by the walker (cache energy is
    /// counted separately through the data-path constants).
    pub walk_load_pj: f64,
    /// L1 access.
    pub l1_pj: f64,
    /// L2 access.
    pub l2_pj: f64,
    /// L3 access.
    pub l3_pj: f64,
    /// DRAM line fetch.
    pub dram_pj: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        EnergyModel {
            tlb_lookup_pj: 4.0,
            stlb_lookup_pj: 12.0,
            walk_load_pj: 8.0,
            l1_pj: 10.0,
            l2_pj: 25.0,
            l3_pj: 100.0,
            dram_pj: 2000.0,
        }
    }
}

/// Counters accumulated by [`crate::memsim::Hierarchy`].
#[derive(Clone, Copy, Debug, Default)]
pub struct SimStats {
    /// Demand data accesses simulated.
    pub accesses: u64,
    /// Total cycles charged (translation + data).
    pub cycles: u64,
    /// Cycles spent in translation only (TLB probes + walks).
    pub translation_cycles: u64,
    /// DTLB hits (any page size).
    pub dtlb_hits: u64,
    /// DTLB misses.
    pub dtlb_misses: u64,
    /// STLB hits after a DTLB miss.
    pub stlb_hits: u64,
    /// Full or partial page-table walks performed.
    pub walks: u64,
    /// Memory accesses issued by the walker for PTEs.
    pub walk_loads: u64,
    /// Walker PTE loads that missed all caches (DRAM energy dominates
    /// translation energy when the PTE working set falls out of L3).
    pub walk_dram_loads: u64,
    /// L1 data hits.
    pub l1_hits: u64,
    /// L2 hits.
    pub l2_hits: u64,
    /// L3 hits.
    pub l3_hits: u64,
    /// DRAM accesses (L3 misses).
    pub dram_accesses: u64,
    /// Prefetch fills issued.
    pub prefetches: u64,
}

impl SimStats {
    /// Mean cycles per access.
    pub fn cpa(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.cycles as f64 / self.accesses as f64
        }
    }

    /// DTLB miss ratio.
    pub fn tlb_miss_rate(&self) -> f64 {
        let total = self.dtlb_hits + self.dtlb_misses;
        if total == 0 {
            0.0
        } else {
            self.dtlb_misses as f64 / total as f64
        }
    }

    /// Share of cycles spent translating (the paper's headline cost).
    pub fn translation_share(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.translation_cycles as f64 / self.cycles as f64
        }
    }

    /// Total memory-system energy (pJ) under `m`, split into
    /// `(translation, data)` — translation = every TLB probe plus the
    /// walker's PTE loads; data = the cache/DRAM traffic of demand
    /// accesses.
    pub fn energy_pj(&self, m: &EnergyModel) -> (f64, f64) {
        let translation = (self.dtlb_hits + self.dtlb_misses) as f64 * m.tlb_lookup_pj
            + self.dtlb_misses as f64 * m.stlb_lookup_pj
            // Each PTE load pays walker logic + a cache-path access; the
            // ones that miss to DRAM pay the line fetch as well.
            + self.walk_loads as f64 * (m.walk_load_pj + m.l1_pj + m.l2_pj)
            + self.walk_dram_loads as f64 * (m.l3_pj + m.dram_pj);
        let data = self.l1_hits as f64 * m.l1_pj
            + self.l2_hits as f64 * (m.l1_pj + m.l2_pj)
            + self.l3_hits as f64 * (m.l1_pj + m.l2_pj + m.l3_pj)
            + self.dram_accesses as f64 * (m.l1_pj + m.l2_pj + m.l3_pj + m.dram_pj)
            + self.prefetches as f64 * m.l2_pj;
        (translation, data)
    }

    /// Fraction of memory-system energy spent on translation (the §2
    /// "up to 15% of a chip's energy" quantity, restricted to the
    /// memory system we model).
    pub fn translation_energy_share(&self, m: &EnergyModel) -> f64 {
        let (t, d) = self.energy_pj(m);
        if t + d == 0.0 {
            0.0
        } else {
            t / (t + d)
        }
    }
}

impl std::fmt::Display for SimStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "accesses={} cycles={} cpa={:.2} translation={:.1}%",
            self.accesses,
            self.cycles,
            self.cpa(),
            self.translation_share() * 100.0
        )?;
        writeln!(
            f,
            "  dtlb: {}/{} miss ({:.2}%)  stlb hits: {}  walks: {} ({} loads)",
            self.dtlb_misses,
            self.dtlb_hits + self.dtlb_misses,
            self.tlb_miss_rate() * 100.0,
            self.stlb_hits,
            self.walks,
            self.walk_loads
        )?;
        write!(
            f,
            "  data: L1 {}  L2 {}  L3 {}  DRAM {}  prefetches {}",
            self.l1_hits, self.l2_hits, self.l3_hits, self.dram_accesses, self.prefetches
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratios_handle_zero() {
        let s = SimStats::default();
        assert_eq!(s.cpa(), 0.0);
        assert_eq!(s.tlb_miss_rate(), 0.0);
        assert_eq!(s.translation_share(), 0.0);
    }

    #[test]
    fn cpa_division() {
        let s = SimStats {
            accesses: 4,
            cycles: 40,
            ..Default::default()
        };
        assert_eq!(s.cpa(), 10.0);
    }

    #[test]
    fn energy_split_counts_translation_events() {
        let m = EnergyModel::default();
        let s = SimStats {
            dtlb_hits: 90,
            dtlb_misses: 10,
            walk_loads: 40,
            l1_hits: 100,
            ..Default::default()
        };
        let (t, d) = s.energy_pj(&m);
        assert_eq!(t, 100.0 * 4.0 + 10.0 * 12.0 + 40.0 * (8.0 + 10.0 + 25.0));
        assert_eq!(d, 100.0 * 10.0);
        assert!(s.translation_energy_share(&m) > 0.0);
    }

    #[test]
    fn physical_mode_has_zero_translation_energy() {
        let m = EnergyModel::default();
        let s = SimStats {
            l1_hits: 50,
            dram_accesses: 5,
            ..Default::default()
        };
        assert_eq!(s.translation_energy_share(&m), 0.0);
    }

    #[test]
    fn paper_claim_translation_energy_significant_under_thrash() {
        // §2: translation can reach ~15% of chip energy. Under a
        // TLB-thrashing virtual workload our memory-system share should
        // land in the same regime (5-40%).
        use crate::memsim::{AddressMode, Hierarchy, PageSize};
        let mut h = Hierarchy::kaby_lake(AddressMode::Virtual(PageSize::P4K));
        let mut rng = crate::testutil::Rng::new(1);
        for _ in 0..200_000 {
            h.access(rng.below(4 << 30) & !3);
        }
        let share = h.stats().translation_energy_share(&EnergyModel::default());
        assert!(
            (0.05..=0.6).contains(&share),
            "translation energy share {share:.3} out of plausible range"
        );
    }
}
