//! Stream prefetcher: detects ascending/descending line streams and
//! fills ahead into L2 (models the paper's "prefetching also helps to
//! hide TLB miss latency when access patterns are predictable").

/// Tracked stream state.
#[derive(Clone, Copy, Debug)]
struct Stream {
    last_line: u64,
    dir: i64,
    confidence: u8,
}

/// A simple multi-stream next-line prefetcher.
///
/// Stream table is a fixed ring (perf: `observe` runs on *every*
/// simulated access — EXPERIMENTS.md §Perf iteration 2).
pub struct Prefetcher {
    streams: [Stream; 8],
    n_streams: usize,
    oldest: usize,
    degree: u32,
    issued: u64,
}

impl Prefetcher {
    /// `degree` lines fetched ahead per confirmed stream access
    /// (0 disables prefetching entirely).
    pub fn new(degree: u32) -> Self {
        Prefetcher {
            streams: [Stream {
                last_line: u64::MAX,
                dir: 0,
                confidence: 0,
            }; 8],
            n_streams: 0,
            oldest: 0,
            degree,
            issued: 0,
        }
    }

    /// Observe a demand access to `line`; returns the lines to fill
    /// ahead into the cache (empty when no stream is confirmed). `out`
    /// is cleared first.
    pub fn observe(&mut self, line: u64, out: &mut Vec<u64>) {
        out.clear();
        if self.degree == 0 {
            return;
        }
        // Match an existing stream (within 2 lines of its head).
        for s in self.streams[..self.n_streams].iter_mut() {
            let delta = line as i64 - s.last_line as i64;
            if delta != 0 && delta.abs() <= 2 && (s.dir == 0 || delta.signum() == s.dir.signum()) {
                s.dir = delta.signum();
                s.last_line = line;
                s.confidence = s.confidence.saturating_add(1);
                if s.confidence >= 2 {
                    for k in 1..=self.degree as i64 {
                        let target = line as i64 + s.dir * k;
                        if target >= 0 {
                            out.push(target as u64);
                        }
                    }
                    self.issued += out.len() as u64;
                }
                return;
            }
            if delta == 0 {
                return; // same line, nothing to learn
            }
        }
        // New stream (bounded table, FIFO replacement via ring index).
        let slot = if self.n_streams < 8 {
            let s = self.n_streams;
            self.n_streams += 1;
            s
        } else {
            let s = self.oldest;
            self.oldest = (self.oldest + 1) % 8;
            s
        };
        self.streams[slot] = Stream {
            last_line: line,
            dir: 0,
            confidence: 0,
        };
    }

    /// Prefetches issued so far.
    pub fn issued(&self) -> u64 {
        self.issued
    }

    /// Forget all streams.
    pub fn reset(&mut self) {
        self.n_streams = 0;
        self.oldest = 0;
        self.issued = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_stream_confirmed() {
        let mut p = Prefetcher::new(2);
        let mut out = Vec::new();
        p.observe(100, &mut out);
        assert!(out.is_empty());
        p.observe(101, &mut out);
        assert!(out.is_empty()); // confidence building
        p.observe(102, &mut out);
        assert_eq!(out, vec![103, 104]);
    }

    #[test]
    fn descending_stream() {
        let mut p = Prefetcher::new(1);
        let mut out = Vec::new();
        for line in [50u64, 49, 48, 47] {
            p.observe(line, &mut out);
        }
        assert_eq!(out, vec![46]);
    }

    #[test]
    fn random_never_prefetches() {
        let mut p = Prefetcher::new(2);
        let mut out = Vec::new();
        let mut total = 0;
        for line in [5u64, 900, 13, 77777, 42, 123456, 7, 999] {
            p.observe(line, &mut out);
            total += out.len();
        }
        assert_eq!(total, 0);
    }

    #[test]
    fn disabled_prefetcher_is_silent() {
        let mut p = Prefetcher::new(0);
        let mut out = Vec::new();
        for line in 0..10u64 {
            p.observe(line, &mut out);
            assert!(out.is_empty());
        }
    }
}
