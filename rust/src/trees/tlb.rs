//! The software leaf-TLB (paper §4.4).
//!
//! The paper argues that once hardware translation is gone, its job —
//! turning a flat index into a physical location in O(1) — can be done
//! by software caches over the tree's translation metadata: "the
//! Iterator optimization is a software page-table-walk cache". The
//! Figure 2 cursor caches exactly *one* leaf, which collapses for
//! strided and random access patterns (GUPS, hash probes) that bounce
//! between leaves. [`LeafTlb`] generalizes it to a set-associative,
//! LRU-evicting cache of leaf translations — the software analogue of a
//! data TLB, with the tree's leaves playing the role of pages.
//!
//! Unlike a hardware TLB there is no shootdown interrupt: relocation
//! safety comes from *generation numbers*. Every entry is stamped with
//! the owning tree's generation at fill time; `TreeArray` bumps its
//! generation whenever a leaf moves (see
//! `TreeArray::relocate_leaf_impl`), so a lookup with a newer
//! generation treats the entry as stale, drops it, and counts an
//! invalidation. This is the scheme Cichlid-style explicit physical
//! memory managers and the Virtual Block Interface rely on: translation
//! metadata is tiny relative to data, so caching (or fully flattening)
//! it is cheap, and a single counter makes invalidation O(1).
//!
//! This module is the *real* software TLB used on the hot path; it is
//! distinct from [`crate::memsim::Tlb`], which merely *models* a
//! hardware TLB's hit/miss behaviour for the simulator.

/// Statistics of one [`LeafTlb`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TlbStats {
    /// Lookups served from the TLB (no tree walk needed).
    pub hits: u64,
    /// Lookups that found no usable entry.
    pub misses: u64,
    /// Valid entries displaced by LRU replacement.
    pub evictions: u64,
    /// Entries dropped because their generation was stale
    /// (the software shootdown path).
    pub invalidations: u64,
}

impl TlbStats {
    /// Hit fraction of all lookups (0 when never used).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// One cached leaf translation: leaf index -> data pointer.
#[derive(Clone, Copy)]
struct TlbEntry {
    /// Leaf index this entry translates (the "virtual page number").
    tag: usize,
    /// Leaf data pointer (the "physical frame").
    ptr: *mut u8,
    /// Elements covered by the leaf (partial last leaf is shorter).
    span: usize,
    /// Tree generation at fill time.
    gen: u64,
    /// LRU stamp (global tick at last touch).
    stamp: u64,
    valid: bool,
}

const EMPTY: TlbEntry = TlbEntry {
    tag: 0,
    ptr: std::ptr::null_mut(),
    span: 0,
    gen: 0,
    stamp: 0,
    valid: false,
};

/// A set-associative, LRU software TLB over tree-leaf translations.
///
/// Configured with a total entry count and an associativity; the set
/// count is `entries / ways` rounded up to a power of two so the set
/// index is a mask of the leaf index. `entries == 0` builds a disabled
/// TLB whose lookups always miss (used to reproduce the bare Figure 2
/// single-leaf cursor for ablations).
pub struct LeafTlb {
    entries: Box<[TlbEntry]>,
    /// Set count minus one (sets are a power of two). Meaningless (0)
    /// when disabled — every path guards on `entries.is_empty()` first.
    set_mask: usize,
    ways: usize,
    tick: u64,
    stats: TlbStats,
}

// SAFETY: a LeafTlb is plain data — cached `(leaf, pointer, span, gen)`
// tuples and counters. Moving it between threads moves bytes;
// *dereferencing* a cached pointer is already unsafe and governed by
// the owner's protocol ([`crate::trees::Cursor`] same-thread,
// [`crate::trees::TreeView`] epoch-pinned). Without this, per-thread
// TLBs could not ride inside `Send` views or sit behind a `Mutex` for
// the shared-TLB ablation strawman.
unsafe impl Send for LeafTlb {}

impl LeafTlb {
    /// Default total entries for cursors ([`crate::trees::TreeArray::cursor`]).
    pub const DEFAULT_ENTRIES: usize = 64;
    /// Default associativity.
    pub const DEFAULT_WAYS: usize = 4;

    /// A TLB with `entries` total entries, `ways`-associative.
    pub fn new(entries: usize, ways: usize) -> Self {
        if entries == 0 {
            return LeafTlb {
                entries: Box::new([]),
                set_mask: 0,
                ways: 0,
                tick: 0,
                stats: TlbStats::default(),
            };
        }
        let ways = ways.clamp(1, entries);
        let sets = entries.div_ceil(ways).next_power_of_two();
        LeafTlb {
            entries: vec![EMPTY; sets * ways].into_boxed_slice(),
            set_mask: sets - 1,
            ways,
            tick: 0,
            stats: TlbStats::default(),
        }
    }

    /// The cursor-default configuration (64 entries, 4-way).
    pub fn default_for_cursor() -> Self {
        LeafTlb::new(Self::DEFAULT_ENTRIES, Self::DEFAULT_WAYS)
    }

    /// True when built with zero entries.
    #[inline]
    pub fn is_disabled(&self) -> bool {
        self.entries.is_empty()
    }

    /// Total entry slots.
    pub fn capacity(&self) -> usize {
        self.entries.len()
    }

    /// Associativity (0 when disabled). `(capacity, ways)` reproduces
    /// this TLB's geometry through [`LeafTlb::new`].
    pub fn ways(&self) -> usize {
        self.ways
    }

    /// Look up leaf `leaf` under the current tree generation `gen`.
    ///
    /// Returns the cached `(data pointer, element span)` on a hit.
    /// An entry whose generation is older than `gen` is stale — it is
    /// invalidated (counted) and the lookup misses, forcing the caller
    /// to re-walk and re-insert (the revalidation protocol).
    #[inline]
    pub fn lookup(&mut self, leaf: usize, gen: u64) -> Option<(*mut u8, usize)> {
        if self.entries.is_empty() {
            self.stats.misses += 1;
            return None;
        }
        let set = (leaf & self.set_mask) * self.ways;
        for e in &mut self.entries[set..set + self.ways] {
            if e.valid && e.tag == leaf {
                if e.gen != gen {
                    e.valid = false;
                    self.stats.invalidations += 1;
                    break;
                }
                self.tick += 1;
                e.stamp = self.tick;
                self.stats.hits += 1;
                return Some((e.ptr, e.span));
            }
        }
        self.stats.misses += 1;
        None
    }

    /// Install the translation for `leaf` (after a tree walk), evicting
    /// the set's LRU entry if the set is full.
    pub fn insert(&mut self, leaf: usize, gen: u64, ptr: *mut u8, span: usize) {
        if self.entries.is_empty() {
            return;
        }
        let set = (leaf & self.set_mask) * self.ways;
        self.tick += 1;
        let tick = self.tick;
        // Reuse the slot already holding this tag, else an invalid slot,
        // else the LRU victim.
        let mut victim = set;
        let mut victim_stamp = u64::MAX;
        for (w, e) in self.entries[set..set + self.ways].iter().enumerate() {
            if e.valid && e.tag == leaf {
                victim = set + w;
                break;
            }
            let stamp = if e.valid { e.stamp } else { 0 };
            if stamp < victim_stamp {
                victim_stamp = stamp;
                victim = set + w;
            }
        }
        let e = &mut self.entries[victim];
        if e.valid && e.tag != leaf {
            self.stats.evictions += 1;
        }
        *e = TlbEntry {
            tag: leaf,
            ptr,
            span,
            gen,
            stamp: tick,
            valid: true,
        };
    }

    /// Drop the entry for `leaf` if present (targeted shootdown).
    pub fn invalidate(&mut self, leaf: usize) {
        if self.entries.is_empty() {
            return;
        }
        let set = (leaf & self.set_mask) * self.ways;
        for e in &mut self.entries[set..set + self.ways] {
            if e.valid && e.tag == leaf {
                e.valid = false;
                self.stats.invalidations += 1;
            }
        }
    }

    /// Drop every entry (full shootdown).
    pub fn flush(&mut self) {
        for e in self.entries.iter_mut() {
            if e.valid {
                e.valid = false;
                self.stats.invalidations += 1;
            }
        }
    }

    /// Counter snapshot.
    pub fn stats(&self) -> TlbStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(x: usize) -> *mut u8 {
        x as *mut u8
    }

    #[test]
    fn hit_after_insert() {
        let mut t = LeafTlb::new(8, 2);
        assert_eq!(t.lookup(3, 0), None);
        t.insert(3, 0, p(0x30), 256);
        assert_eq!(t.lookup(3, 0), Some((p(0x30), 256)));
        let s = t.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
    }

    #[test]
    fn stale_generation_invalidates() {
        let mut t = LeafTlb::new(8, 2);
        t.insert(5, 1, p(0x50), 10);
        // Generation moved on (a leaf was relocated): the entry is dead.
        assert_eq!(t.lookup(5, 2), None);
        assert_eq!(t.stats().invalidations, 1);
        // And it's really gone, not resurrected at the old generation.
        assert_eq!(t.lookup(5, 1), None);
    }

    #[test]
    fn lru_evicts_oldest_in_set() {
        // 1 set, 2 ways: fill A, B; touch A; insert C -> B evicted.
        let mut t = LeafTlb::new(2, 2);
        t.insert(0, 0, p(0xA0), 1);
        t.insert(1, 0, p(0xB0), 1);
        assert!(t.lookup(0, 0).is_some()); // A freshened
        t.insert(2, 0, p(0xC0), 1);
        assert_eq!(t.stats().evictions, 1);
        assert!(t.lookup(0, 0).is_some(), "recently used survives");
        assert!(t.lookup(1, 0).is_none(), "LRU victim gone");
        assert!(t.lookup(2, 0).is_some());
    }

    #[test]
    fn set_indexing_isolates_sets() {
        // 4 sets, 1 way: leaves 0..4 land in distinct sets; 4 aliases 0.
        let mut t = LeafTlb::new(4, 1);
        for l in 0..4 {
            t.insert(l, 0, p(l * 16 + 16), 1);
        }
        for l in 0..4 {
            assert_eq!(t.lookup(l, 0), Some((p(l * 16 + 16), 1)));
        }
        t.insert(4, 0, p(0x99), 1);
        assert!(t.lookup(0, 0).is_none(), "conflict-evicted by alias");
        assert!(t.lookup(4, 0).is_some());
        assert_eq!(t.stats().evictions, 1);
    }

    #[test]
    fn reinsert_same_tag_updates_in_place() {
        let mut t = LeafTlb::new(2, 2);
        t.insert(7, 0, p(0x70), 1);
        t.insert(7, 1, p(0x71), 2);
        assert_eq!(t.stats().evictions, 0, "same tag must not evict");
        assert_eq!(t.lookup(7, 1), Some((p(0x71), 2)));
    }

    #[test]
    fn disabled_tlb_always_misses() {
        let mut t = LeafTlb::new(0, 4);
        assert!(t.is_disabled());
        t.insert(0, 0, p(0x10), 1);
        assert_eq!(t.lookup(0, 0), None);
        assert_eq!(t.stats().hits, 0);
    }

    #[test]
    fn flush_and_targeted_invalidate() {
        let mut t = LeafTlb::new(8, 2);
        t.insert(1, 0, p(0x10), 1);
        t.insert(2, 0, p(0x20), 1);
        t.invalidate(1);
        assert!(t.lookup(1, 0).is_none());
        assert!(t.lookup(2, 0).is_some());
        t.flush();
        assert!(t.lookup(2, 0).is_none());
        assert_eq!(t.stats().invalidations, 2);
    }

    #[test]
    fn hit_rate_math() {
        let mut t = LeafTlb::new(4, 4);
        t.insert(0, 0, p(0x10), 1);
        for _ in 0..3 {
            t.lookup(0, 0);
        }
        t.lookup(9, 0);
        let s = t.stats();
        assert!((s.hit_rate() - 0.75).abs() < 1e-12);
    }
}
