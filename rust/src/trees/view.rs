//! Concurrent read-side translation: shared tree views with per-thread
//! leaf-TLBs.
//!
//! PR 2 made *one* cursor fast; this module makes the whole machine
//! fast. A [`TreeView`] is a `Send` read handle over a shared
//! [`TreeArray`]: many views — one per worker thread — read one tree
//! concurrently, and each keeps its **own** [`LeafTlb`] hot set
//! (llfree-rs's CPU-local-state-over-shared-atomics idiom applied to
//! translation). There is no shared mutable TLB and no lock anywhere on
//! the lookup path; the only shared state a lookup touches is the
//! tree's atomic translation metadata (root / flat leaf table /
//! generation) and the arena epoch, all read-only in steady state.
//!
//! # Safety protocol (reads vs relocation vs writers)
//!
//! Four layers, each handling one hazard:
//!
//! 1. **Torn translation** — every pointer relocation patches (interior
//!    child slots, the root, the flat leaf table) is an atomic 8-byte
//!    store; views walk with `Acquire` loads. A reader sees the old or
//!    the new location, never a mix, and the copy into the new block
//!    happens-before its publication.
//! 2. **Stale cached translation** — views stamp TLB entries with the
//!    tree generation and snapshot the arena epoch
//!    ([`crate::pmem::ArenaEpoch`]); every access pins the epoch first
//!    and flushes the TLB when it moved (arena-wide shootdown: a move
//!    in *any* structure of the pool invalidates every view's cache).
//! 3. **Use-after-free of the displaced block** — checking counters "on
//!    the next access" cannot protect a read already in flight, so the
//!    view is a registered epoch reader: the pin also publishes "I may
//!    hold translations from epoch `e`", and
//!    [`TreeArray::migrate_leaf_concurrent`] retires displaced blocks
//!    into limbo instead of freeing them until every registered reader
//!    has pinned past the move. A view's translation therefore always
//!    points at a block that is either current or retired-but-unfreed —
//!    and both hold identical bytes (the copy precedes publication).
//!
//! 4. **Torn data reads under live writers** — a
//!    [`crate::trees::TreeWriter`] may mutate a leaf while a view reads
//!    it, so **every** view read path (`get`, `get_batch`, `to_vec`,
//!    `for_each_leaf_run`) brackets each leaf read between two loads of
//!    the leaf's sequence word (the per-leaf seqlock; see the
//!    [`TreeArray`] "Writers" docs) and retries on an odd or changed
//!    value. A generation re-check inside the bracket pins the
//!    translation to the *current* block, so a pre-relocation
//!    translation can never satisfy a post-relocation read (the stale
//!    block's bytes stop being updated the moment the leaf moves).
//!    When no writer exists the bracket costs two uncontended atomic
//!    loads per leaf run and never retries. Views are therefore always
//!    safe under writers — one contract, every path; the bulk paths
//!    buy it by snapshotting each leaf run into a scratch buffer
//!    before handing it to the callback.
//! 5. **Evicted leaves (software page faults)** — when the tree is
//!    registered evictable, a leaf's bytes may be in swap. Each
//!    bracket checks the leaf's swap word after its begin-load (the
//!    evictor publishes the word before releasing the leaf seqlock, so
//!    the bracket cannot miss it); a hit diverts to
//!    `TreeArray::fault_leaf`, which brings the payload back through
//!    the installed [`crate::pmem::LeafFaulter`] *under the leaf's
//!    seqlock* and republishes the translation. The view then simply
//!    retries. With no faulter installed the read surfaces
//!    [`Error::SwappedOut`]; a permanently failing backing surfaces
//!    [`Error::SwapFaultFailed`] — typed errors on the `Result` paths,
//!    a documented panic on the `_unchecked`/`to_vec` conveniences.
//!
//! What stays on the caller: data writes go through
//! [`crate::trees::TreeWriter`] (or `&mut TreeArray` while no view is
//! alive) — never both regimes at once with unchecked paths (the
//! [`TreeArray::writer`] contract). Relocation under live views must go
//! through [`TreeArray::migrate_leaf_concurrent`]; the immediate-free
//! forms ([`TreeArray::migrate_leaf`] / [`TreeArray::migrate_leaf_shared`])
//! keep their no-concurrent-access contract.

use std::sync::atomic::{fence, Ordering};

use crate::error::{Error, Result};
use crate::pmem::epoch::ReaderSlot;
use crate::pmem::{BlockAlloc, BlockAllocator};
use crate::trees::tlb::{LeafTlb, TlbStats};
use crate::trees::tree_array::{Pod, TreeArray, SWAP_RESIDENT};

/// A `Send` shared read view over a [`TreeArray`], with a private
/// leaf-TLB and an arena-epoch registration. Create one per worker via
/// [`TreeArray::view`] (or `clone` an existing one); see the module
/// docs for the concurrency contract.
pub struct TreeView<'t, 'a, T: Pod, A: BlockAlloc = BlockAllocator> {
    tree: &'t TreeArray<'a, T, A>,
    /// This view's private translation cache — never shared, never
    /// locked.
    tlb: LeafTlb,
    /// Tree generation TLB entries are stamped against.
    gen: u64,
    /// Arena epoch last observed; the TLB flushes when it moves.
    epoch_seen: u64,
    /// Registration with the arena epoch (pinned on every access).
    slot: ReaderSlot<'a>,
    /// Full translations performed (TLB misses that walked/indexed).
    walks: u64,
    /// Seq-bracket retries: reads re-run because a writer or a
    /// relocation overlapped them (hazard 4 in the module docs).
    seq_retries: u64,
    /// Software page faults this view triggered: reads that found their
    /// leaf evicted and brought it back in (hazard 5).
    faults: u64,
}

// SAFETY: a TreeView is a read-only handle. Its raw pointers (inside
// the LeafTlb) point into the allocator's arena, which outlives 'a and
// is never unmapped while the allocator exists; dereferences happen
// only on the owning thread after the epoch pin + generation check
// described in the module docs, and blocks those pointers name are kept
// allocated (limbo) until this view quiesces. The remaining fields are
// `&TreeArray` (Sync for T: Sync — all interior mutability is atomic),
// a ReaderSlot (Arc + &ArenaEpoch, both thread-safe), and counters.
unsafe impl<T: Pod + Sync, A: BlockAlloc> Send for TreeView<'_, '_, T, A> {}

impl<'t, 'a, T: Pod + Sync, A: BlockAlloc> TreeView<'t, 'a, T, A> {
    pub(crate) fn new(tree: &'t TreeArray<'a, T, A>, tlb: LeafTlb) -> Self {
        let slot = tree.alloc.epoch().register();
        let epoch_seen = slot.pin();
        TreeView {
            tree,
            tlb,
            gen: tree.generation(),
            epoch_seen,
            slot,
            walks: 0,
            seq_retries: 0,
            faults: 0,
        }
    }

    /// Element count of the underlying tree.
    #[inline]
    pub fn len(&self) -> usize {
        self.tree.len()
    }

    /// True when the underlying tree holds no elements.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.tree.is_empty()
    }

    /// Leaf blocks of the underlying tree.
    #[inline]
    pub fn nleaves(&self) -> usize {
        self.tree.nleaves()
    }

    /// Geometry metadata of the underlying tree (leaf capacity etc. —
    /// what blocked kernels need to chunk index sets by leaf).
    #[inline]
    pub fn geometry(&self) -> crate::trees::TreeGeometry {
        self.tree.geometry()
    }

    /// Pin the arena epoch for the accesses that follow (hazard 3 in
    /// the module docs) and run the shootdown checks (hazard 2): flush
    /// the TLB wholesale when the epoch moved, refresh the generation
    /// stamp entries validate against.
    ///
    /// Must run before every translation batch; everything dereferenced
    /// until the next pin is covered by this pin's epoch.
    ///
    /// LOCKSTEP: `TreeWriter::pin` in `write.rs` is a deliberate twin —
    /// the flush-on-epoch-move + generation-restamp protocol must
    /// change in both places or neither.
    #[inline]
    fn pin(&mut self) {
        let e = self.slot.pin();
        if e != self.epoch_seen {
            self.epoch_seen = e;
            self.tlb.flush();
        }
        // Entries self-invalidate on generation mismatch; track the
        // current value for lookups/inserts. (Relocation bumps the
        // generation before the epoch, so a fresh epoch implies a fresh
        // generation here.)
        self.gen = self.tree.generation();
    }

    /// Translate `leaf_idx` through this view's TLB; miss falls through
    /// to the tree's active translation mode (flat table or walk).
    #[inline]
    fn leaf_translate(&mut self, leaf_idx: usize) -> (*const T, usize) {
        if let Some((p, span)) = self.tlb.lookup(leaf_idx, self.gen) {
            return (p as *const T, span);
        }
        let (p, span) = self.tree.leaf_ptr(leaf_idx);
        self.walks += 1;
        // Recency for eviction policy: a full translation means this
        // leaf left the hot set at some point — cheap enough to stamp
        // here, and misses are exactly the signal mmd wants (TLB hits
        // would stamp every access and serialize the hot path on the
        // clock).
        self.tree.note_touch(leaf_idx);
        self.tlb.insert(leaf_idx, self.gen, p as *mut u8, span);
        (p as *const T, span)
    }

    /// Hazard-5 half of the bracket: load the leaf's swap word
    /// (`Acquire`, so a hit happens-after the evictor's publication)
    /// and fault the leaf back in when it is out. Returns `true` when a
    /// fault ran (caller must re-pin and retry its bracket — the fault
    /// republished the translation and bumped the generation).
    #[inline]
    fn fault_if_swapped(&mut self, leaf: usize) -> Result<bool> {
        if self.tree.swap_word(leaf).load(Ordering::Acquire) == SWAP_RESIDENT {
            return Ok(false);
        }
        self.faults += 1;
        // fault_leaf serializes on the leaf seqlock and re-checks under
        // it, so concurrent views racing here coalesce: one does the
        // I/O, the rest see Ok(false) and retry into the restored leaf.
        self.tree.fault_leaf(leaf)?;
        self.pin();
        Ok(true)
    }

    /// One lap of the reader retry path (hazard 4): count it, back off
    /// (spin first, donate the timeslice on long waits — a mid-copy
    /// relocation holds a leaf for a whole memcpy), and re-pin so the
    /// next attempt revalidates against fresh generation/epoch values.
    #[inline]
    fn seq_retry(&mut self, tries: &mut u32) {
        self.seq_retries += 1;
        self.tree.note_seq_retry();
        *tries += 1;
        if *tries & 0x3F == 0 {
            std::thread::yield_now();
        } else {
            std::hint::spin_loop();
        }
        self.pin();
    }

    /// Read element `i` (bounds-checked). On an evictable tree this may
    /// fault the leaf in; fault failures surface as
    /// [`Error::SwappedOut`] (no faulter installed) or
    /// [`Error::SwapFaultFailed`] (backing store gave up).
    pub fn get(&mut self, i: usize) -> Result<T> {
        if i >= self.len() {
            return Err(Error::IndexOutOfBounds {
                index: i,
                len: self.len(),
            });
        }
        // SAFETY: bounds checked.
        unsafe { self.try_get_unchecked(i) }
    }

    /// Read element `i` without bounds checking.
    ///
    /// Convenience wrapper over [`TreeView::try_get_unchecked`].
    ///
    /// # Panics
    /// When the leaf is evicted and cannot be faulted back in (no
    /// faulter installed, or the swap backing failed permanently). Use
    /// the `try_` form where swap failures must be handled.
    ///
    /// # Safety
    /// `i < self.len()`.
    #[inline]
    pub unsafe fn get_unchecked(&mut self, i: usize) -> T {
        // SAFETY: forwarded caller contract.
        unsafe { self.try_get_unchecked(i) }.expect("swap fault-in failed in TreeView::get_unchecked")
    }

    /// Read element `i` without bounds checking, seq-bracketed against
    /// concurrent writers and relocation (module docs, hazard 4): the
    /// value returned was the element's committed value at some point
    /// inside the call, never a torn or mid-write snapshot. An evicted
    /// leaf is faulted back in transparently (hazard 5).
    ///
    /// # Safety
    /// `i < self.len()`.
    #[inline]
    pub unsafe fn try_get_unchecked(&mut self, i: usize) -> Result<T> {
        self.pin();
        let shift = self.tree.geo.leaf_cap.trailing_zeros();
        let leaf = i >> shift;
        let off = i & (self.tree.geo.leaf_cap - 1);
        let mut tries = 0u32;
        loop {
            let (p, _) = self.leaf_translate(leaf);
            let s1 = self.tree.seq_word(leaf).load(Ordering::Acquire);
            // The bracket vouches only for a *current* translation: the
            // generation re-check orders "translation still current"
            // inside [s1, s2] — a relocation completed before s1 bumped
            // the generation under the seqlock, so it cannot pass both
            // tests (see the TreeArray "Writers" docs).
            if s1 & 1 == 1 || self.tree.generation() != self.gen {
                self.seq_retry(&mut tries);
                continue;
            }
            // Evicted? Fault it in and re-run the bracket. (An eviction
            // racing past s1 is caught by the s2 compare below — the
            // evictor holds the seqlock — so the check cannot be
            // missed, only seen one lap late.)
            if self.fault_if_swapped(leaf)? {
                continue;
            }
            // SAFETY: in-bounds per caller; aligned per the Pod
            // contract; volatile because the load may race a writer —
            // a racy value never escapes (discarded below).
            let v = unsafe { p.add(off).read_volatile() };
            fence(Ordering::Acquire);
            if self.tree.seq_word(leaf).load(Ordering::Relaxed) == s1 {
                return Ok(v);
            }
            self.seq_retry(&mut tries);
        }
    }

    /// Read many elements (`out[k]` = element `idxs[k]`), pinned once
    /// and grouped by leaf so each distinct leaf run costs one TLB
    /// probe and one seq bracket, exactly like [`TreeArray::get_batch`]
    /// plus the writer protocol: a run overlapped by a write or a
    /// relocation of its leaf is retried wholesale. Evicted leaves are
    /// faulted in per run (hazard 5).
    pub fn get_batch(&mut self, idxs: &[usize]) -> Result<Vec<T>> {
        self.tree.check_batch(idxs)?;
        self.pin();
        let mut out = vec![T::default(); idxs.len()];
        let order = self.tree.leaf_order(idxs);
        let shift = self.tree.geo.leaf_cap.trailing_zeros();
        let mask = self.tree.geo.leaf_cap - 1;
        let mut k = 0;
        while k < order.len() {
            let leaf = idxs[order[k] as usize] >> shift;
            let mut e = k + 1;
            while e < order.len() && idxs[order[e] as usize] >> shift == leaf {
                e += 1;
            }
            let mut tries = 0u32;
            loop {
                let (base, _) = self.leaf_translate(leaf);
                let s1 = self.tree.seq_word(leaf).load(Ordering::Acquire);
                if s1 & 1 == 1 || self.tree.generation() != self.gen {
                    self.seq_retry(&mut tries);
                    continue;
                }
                if self.fault_if_swapped(leaf)? {
                    continue;
                }
                for &pos in &order[k..e] {
                    let pos = pos as usize;
                    // SAFETY: bounds checked above; offset < leaf span;
                    // volatile — racy values are discarded below.
                    out[pos] = unsafe { base.add(idxs[pos] & mask).read_volatile() };
                }
                fence(Ordering::Acquire);
                if self.tree.seq_word(leaf).load(Ordering::Relaxed) == s1 {
                    break;
                }
                // Rewriting out[pos] on retry is idempotent.
                self.seq_retry(&mut tries);
            }
            k = e;
        }
        // Batched pinning: one pin covered the whole batch where
        // per-access pinning would have paid idxs.len() (accounting
        // only; retries re-pin and count themselves).
        self.slot.record_saved_pins(idxs.len().saturating_sub(1) as u64);
        Ok(out)
    }

    /// Snapshot one whole leaf into `buf` under a seq bracket: the
    /// bytes handed back are a committed state of the leaf (no torn or
    /// mid-write values), faulted in first if evicted. The bulk paths
    /// ([`TreeView::for_each_leaf_run`], [`TreeView::try_to_vec`]) are
    /// built on this — copying under the bracket is what lets them keep
    /// the "views are always safe under writers" contract while still
    /// handing out slices.
    fn read_leaf_snapshot(&mut self, leaf: usize, buf: &mut Vec<T>) -> Result<usize> {
        let mut tries = 0u32;
        loop {
            let (p, span) = self.leaf_translate(leaf);
            let s1 = self.tree.seq_word(leaf).load(Ordering::Acquire);
            if s1 & 1 == 1 || self.tree.generation() != self.gen {
                self.seq_retry(&mut tries);
                continue;
            }
            if self.fault_if_swapped(leaf)? {
                continue;
            }
            buf.clear();
            buf.resize(span, T::default());
            for (j, slot) in buf.iter_mut().enumerate() {
                // SAFETY: j < span, the leaf's element count; volatile
                // — a racy value never escapes (discarded below).
                *slot = unsafe { p.add(j).read_volatile() };
            }
            fence(Ordering::Acquire);
            if self.tree.seq_word(leaf).load(Ordering::Relaxed) == s1 {
                return Ok(span);
            }
            self.seq_retry(&mut tries);
        }
    }

    /// Visit `idxs` grouped into per-leaf runs (the read-side analogue
    /// of [`TreeArray::for_each_leaf_run`]), translated through this
    /// view's TLB under one pin. Each run's leaf is snapshotted under a
    /// seq bracket before the callback sees it, so this is safe under
    /// concurrent writers like every other view path — the callback
    /// gets a committed state of the leaf, at the cost of one leaf-size
    /// copy per run (reused buffer, no per-run allocation in steady
    /// state). The slice is valid only inside the callback — do not
    /// stash it.
    pub fn for_each_leaf_run<F>(&mut self, idxs: &[usize], mut visit: F) -> Result<()>
    where
        F: FnMut(usize, &[T], &[u32]),
    {
        self.tree.check_batch(idxs)?;
        self.pin();
        let order = self.tree.leaf_order(idxs);
        let shift = self.tree.geo.leaf_cap.trailing_zeros();
        let mut buf: Vec<T> = Vec::new();
        let mut k = 0;
        while k < order.len() {
            let leaf = idxs[order[k] as usize] >> shift;
            let mut e = k + 1;
            while e < order.len() && idxs[order[e] as usize] >> shift == leaf {
                e += 1;
            }
            let span = self.read_leaf_snapshot(leaf, &mut buf)?;
            visit(leaf, &buf[..span], &order[k..e]);
            k = e;
        }
        // One pin for the whole run set (vs one per access).
        self.slot.record_saved_pins(idxs.len().saturating_sub(1) as u64);
        Ok(())
    }

    /// Copy the whole array out, one seq-bracketed snapshot per leaf —
    /// safe under concurrent writers (each leaf is a committed state;
    /// the vec as a whole is per-leaf atomic, not globally atomic).
    ///
    /// # Panics
    /// When an evicted leaf cannot be faulted back in — use
    /// [`TreeView::try_to_vec`] where swap failures must be handled.
    pub fn to_vec(&mut self) -> Vec<T> {
        self.try_to_vec().expect("swap fault-in failed in TreeView::to_vec")
    }

    /// [`TreeView::to_vec`] with fault failures surfaced as typed
    /// errors instead of a panic.
    pub fn try_to_vec(&mut self) -> Result<Vec<T>> {
        self.pin();
        let mut out = Vec::with_capacity(self.len());
        let mut buf: Vec<T> = Vec::new();
        for leaf in 0..self.nleaves() {
            let span = self.read_leaf_snapshot(leaf, &mut buf)?;
            out.extend_from_slice(&buf[..span]);
        }
        // One pin for the whole copy (vs one per leaf).
        self.slot
            .record_saved_pins(self.nleaves().saturating_sub(1) as u64);
        Ok(out)
    }

    /// Go offline: reclamation stops waiting on this view until its
    /// next access. Call when a worker idles between read bursts.
    pub fn park(&self) {
        self.slot.unpin();
    }

    /// This view's private TLB counters.
    pub fn tlb_stats(&self) -> TlbStats {
        self.tlb.stats()
    }

    /// Full translations (TLB misses) this view performed.
    pub fn walks(&self) -> u64 {
        self.walks
    }

    /// Seq-bracket retries: reads re-run because a writer or a
    /// relocation overlapped them. 0 on writer-free workloads.
    pub fn seq_retries(&self) -> u64 {
        self.seq_retries
    }

    /// Software page faults this view triggered (reads that found their
    /// leaf evicted). 0 on fully-resident workloads.
    pub fn faults(&self) -> u64 {
        self.faults
    }
}

/// Cloning spawns a *fresh* view of the same tree: same TLB geometry
/// but an empty cache, zeroed counters, and its own epoch registration
/// — the way to fan one view out across scoped worker threads.
impl<T: Pod + Sync, A: BlockAlloc> Clone for TreeView<'_, '_, T, A> {
    fn clone(&self) -> Self {
        TreeView::new(self.tree, LeafTlb::new(self.tlb.capacity(), self.tlb.ways()))
    }
}

impl<T: Pod, A: BlockAlloc> std::fmt::Debug for TreeView<'_, '_, T, A> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "TreeView {{ len: {}, gen: {}, epoch: {}, walks: {}, tlb: {:?} }}",
            self.tree.len(),
            self.gen,
            self.epoch_seen,
            self.walks,
            self.tlb.stats()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::{BlockAllocator, ShardedAllocator};
    use crate::testutil::Rng;

    fn filled<A: BlockAlloc>(a: &A, n: usize) -> (TreeArray<'_, u32, A>, Vec<u32>) {
        let mut t: TreeArray<u32, A> = TreeArray::new(a, n).unwrap();
        let data: Vec<u32> = (0..n as u32).map(|i| i.wrapping_mul(2654435761)).collect();
        t.copy_from_slice(&data).unwrap();
        (t, data)
    }

    #[test]
    fn view_reads_match_gets() {
        let a = BlockAllocator::new(1024, 1 << 12).unwrap();
        let (t, data) = filled(&a, 256 * 10 + 7);
        let mut v = t.view();
        let mut rng = Rng::new(5);
        for _ in 0..500 {
            let i = rng.range(0, data.len());
            assert_eq!(v.get(i).unwrap(), data[i]);
        }
        assert_eq!(v.to_vec(), data);
        assert!(v.get(data.len()).is_err());
    }

    #[test]
    fn view_tlb_serves_revisits() {
        let a = BlockAllocator::new(1024, 1 << 12).unwrap();
        let (t, data) = filled(&a, 256 * 4);
        let mut v = t.view();
        assert_eq!(v.get(10).unwrap(), data[10]); // walk leaf 0
        assert_eq!(v.get(300).unwrap(), data[300]); // walk leaf 1
        assert_eq!(v.get(20).unwrap(), data[20]); // leaf 0: TLB hit
        assert_eq!(v.walks(), 2, "revisit must not re-translate");
        assert_eq!(v.tlb_stats().hits, 1);
    }

    #[test]
    fn view_get_batch_matches_tree_batch() {
        let a = ShardedAllocator::with_shards(1024, 1 << 12, 4).unwrap();
        let (t, data) = filled(&a, 256 * 20 + 3);
        let mut rng = Rng::new(9);
        let idxs: Vec<usize> = (0..2000).map(|_| rng.range(0, data.len())).collect();
        let mut v = t.view();
        let got = v.get_batch(&idxs).unwrap();
        for (k, &i) in idxs.iter().enumerate() {
            assert_eq!(got[k], data[i]);
        }
        assert!(v.get_batch(&[0, data.len()]).is_err());
    }

    #[test]
    fn view_revalidates_after_concurrent_migration() {
        // Single-threaded shape of the shootdown: view caches leaf 0,
        // the leaf migrates (deferred free), the next read must flush
        // and re-translate — and the displaced block must stay in limbo
        // until this view quiesces.
        let a = BlockAllocator::new(1024, 256).unwrap();
        let (t, data) = filled(&a, 256 * 4);
        let mut v = t.view();
        assert_eq!(v.get(10).unwrap(), data[10]);
        let walks0 = v.walks();
        // SAFETY: readers are epoch-registered views; no raw slices.
        unsafe { t.migrate_leaf_concurrent(0) }.unwrap();
        assert_eq!(a.epoch().limbo_len(), 1, "displaced block must be in limbo");
        assert_eq!(a.epoch().try_reclaim(&a), 0, "view has not quiesced yet");
        assert_eq!(v.get(10).unwrap(), data[10], "stale read after migration");
        assert!(v.walks() > walks0, "flush must force a fresh translation");
        assert!(v.tlb_stats().invalidations >= 1);
        // The read pinned the post-move epoch: now the block reclaims.
        assert_eq!(a.epoch().try_reclaim(&a), 1);
    }

    #[test]
    fn dropping_views_unblocks_reclaim() {
        let a = BlockAllocator::new(1024, 256).unwrap();
        let (t, data) = filled(&a, 256 * 2);
        let v2 = {
            let mut v1 = t.view();
            let mut v2 = v1.clone();
            assert_eq!(v1.get(1).unwrap(), data[1]);
            assert_eq!(v2.get(1).unwrap(), data[1]);
            // SAFETY: readers are epoch-registered views.
            unsafe { t.migrate_leaf_concurrent(0) }.unwrap();
            assert_eq!(a.epoch().try_reclaim(&a), 0, "both views stale");
            v2
        }; // v1 dropped (deregistered)
        assert_eq!(a.epoch().try_reclaim(&a), 0, "v2 still stale");
        drop(v2);
        assert_eq!(a.epoch().try_reclaim(&a), 1, "no readers left");
        assert_eq!(t.to_vec(), data);
    }

    #[test]
    fn parked_view_does_not_stall_reclaim() {
        let a = BlockAllocator::new(1024, 256).unwrap();
        let (t, data) = filled(&a, 256 * 2);
        let mut v = t.view();
        assert_eq!(v.get(0).unwrap(), data[0]);
        v.park();
        // SAFETY: readers are epoch-registered views.
        unsafe { t.migrate_leaf_concurrent(0) }.unwrap();
        assert_eq!(a.epoch().try_reclaim(&a), 1, "parked view is offline");
        // Waking up revalidates as usual.
        assert_eq!(v.get(0).unwrap(), data[0]);
    }

    #[test]
    fn view_faults_evicted_leaves_back_in() {
        use crate::pmem::SwapPool;
        let a = BlockAllocator::new(1024, 256).unwrap();
        let (t, data) = filled(&a, 256 * 4);
        let swap = SwapPool::anonymous(&a).unwrap();
        // SAFETY: `swap` outlives the faulter (cleared below).
        unsafe { t.install_faulter(&swap) };
        let mut v = t.view();
        assert_eq!(v.get(10).unwrap(), data[10]); // leaf 0 cached in the TLB
        // SAFETY: accessors are fault-capable (faulter installed).
        unsafe { t.evict_leaf_via(0, &swap) }.unwrap();
        unsafe { t.evict_leaf_via(2, &swap) }.unwrap();
        assert_eq!(t.swapped_leaves(), 2);
        // Demand fault through every read path.
        assert_eq!(v.get(10).unwrap(), data[10], "get must fault leaf 0 in");
        assert_eq!(v.faults(), 1);
        assert!(!t.leaf_swapped(0));
        let idxs = [256 * 2 + 5, 256 * 2 + 9, 3];
        let got = v.get_batch(&idxs).unwrap();
        assert_eq!(got, idxs.iter().map(|&i| data[i]).collect::<Vec<_>>());
        assert_eq!(v.faults(), 2, "get_batch must fault leaf 2 in");
        assert_eq!(t.swapped_leaves(), 0);
        // to_vec faults too (re-evict one first).
        unsafe { t.evict_leaf_via(1, &swap) }.unwrap();
        assert_eq!(v.to_vec(), data, "to_vec must fault leaf 1 in");
        assert_eq!(v.faults(), 3);
        t.clear_faulter();
    }

    #[test]
    fn view_fault_without_faulter_is_a_typed_error() {
        use crate::pmem::SwapPool;
        let a = BlockAllocator::new(1024, 256).unwrap();
        let (t, data) = filled(&a, 256 * 2);
        let swap = SwapPool::anonymous(&a).unwrap();
        // SAFETY: no faulter installed — that is the point: eviction
        // only needs fault-capable accessors when accessors race it,
        // and this test's view only reads after the typed error check.
        unsafe { t.evict_leaf_via(1, &swap) }.unwrap();
        let mut v = t.view();
        assert_eq!(v.get(0).unwrap(), data[0], "resident leaf still reads");
        assert!(
            matches!(v.get(300), Err(Error::SwappedOut(_))),
            "evicted leaf without a faulter must be a typed error"
        );
        assert!(v.try_to_vec().is_err());
        // Install the faulter: the same read now succeeds.
        // SAFETY: `swap` outlives the faulter (cleared below).
        unsafe { t.install_faulter(&swap) };
        assert_eq!(v.get(300).unwrap(), data[300]);
        assert_eq!(v.to_vec(), data);
        t.clear_faulter();
    }

    #[test]
    fn bulk_paths_snapshot_under_writers() {
        // Satellite: for_each_leaf_run/to_vec are seq-bracketed — a
        // writer mid-flight on a leaf can no longer hand the callback a
        // torn slice. Lock a leaf like a writer would, poke bytes, and
        // check the bulk read retries until release (probed from a
        // helper thread so the main thread can hold the lock).
        let a = BlockAllocator::new(1024, 256).unwrap();
        let (t, data) = filled(&a, 256 * 2);
        let t = &t;
        let guard = t.seq_lock(0).0;
        let done = std::sync::atomic::AtomicBool::new(false);
        let done = &done;
        std::thread::scope(|s| {
            let reader = s.spawn(move || {
                let mut v = t.view();
                let out = v.to_vec();
                done.store(true, Ordering::Release);
                (out, v.seq_retries())
            });
            std::thread::sleep(std::time::Duration::from_millis(20));
            assert!(!done.load(Ordering::Acquire), "to_vec must wait out the in-flight leaf");
            drop(guard);
            let (out, retries) = reader.join().unwrap();
            assert_eq!(out, data);
            assert!(retries > 0, "the bracket must have retried");
        });
    }

    #[test]
    fn scoped_threads_share_one_tree() {
        // The north-star shape: N threads, one tree, per-thread TLBs.
        let a = ShardedAllocator::with_shards(1024, 1 << 12, 4).unwrap();
        let (t, data) = filled(&a, 256 * 16);
        t.enable_flat_table();
        let data = &data;
        let t = &t;
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..4)
                .map(|tid| {
                    s.spawn(move || {
                        let mut v = t.view();
                        let mut rng = Rng::new(tid as u64 + 1);
                        for _ in 0..2000 {
                            let i = rng.range(0, data.len());
                            assert_eq!(v.get(i).unwrap(), data[i]);
                        }
                        v.tlb_stats()
                    })
                })
                .collect();
            for h in handles {
                let stats = h.join().unwrap();
                assert!(stats.hits > 0, "per-thread TLB never hit: {stats:?}");
            }
        });
    }
}
