//! "Arrays as trees" (paper §3.2, after Siebert [11]).
//!
//! Large arrays cannot be one contiguous allocation when the OS only
//! hands out fixed 32 KB blocks, so they become shallow trees: interior
//! nodes hold child block pointers, leaves hold data (Figure 1). With
//! 32 KB nodes and 8-byte child pointers the fanout is 4096, so depth-3
//! trees address ~536 GB and depth-4 ~2 PB (the paper's footnote 1).
//!
//! * [`TreeArray`] — the real data structure, generic over any
//!   [`crate::pmem::BlockAlloc`] pool (mutex baseline or the sharded
//!   lock-free allocator). Offers three translation modes — naive walk,
//!   TLB-backed cursor, flat leaf table — plus batched accessors that
//!   amortize translation over sorted index runs.
//! * [`Cursor`] — the Figure 2 iterator optimization generalized: a
//!   cached leaf pointer backed by a [`LeafTlb`], turning sequential
//!   access into a pointer bump and *revisiting* random access into an
//!   O(1) TLB probe (a software PTW cache, §4.4).
//! * [`LeafTlb`] — the set-associative, LRU software leaf-TLB with
//!   generation-based shootdown (this is the *real* software TLB; the
//!   simulator's hardware-TLB model lives in [`crate::memsim`]).
//! * [`TreeView`] — the concurrent read side: a `Send` shared view with
//!   a *per-thread* leaf-TLB and arena-epoch registration, so N worker
//!   threads read one tree with no lock on the lookup path, safely
//!   coexisting with [`TreeArray::migrate_leaf_concurrent`]'s
//!   epoch-deferred relocation — and, via per-leaf seqlock brackets,
//!   with live [`TreeWriter`]s. Views (and writers) are
//!   **fault-capable**: touching a leaf the daemon evicted takes a
//!   software page fault — the payload is read back through the tree's
//!   installed [`crate::pmem::LeafFaulter`] and re-adopted under the
//!   leaf's seqlock, so eviction is invisible to correctness and costs
//!   only latency.
//! * [`TreeWriter`] — the concurrent write side: a `Send` write handle
//!   that takes a per-leaf **seqlock** for each mutation, so M writers,
//!   N view readers, and the mmd compactor's relocation all run against
//!   one tree with no global lock (relocation acquires the same
//!   seqlock, so a leaf is never simultaneously written and moved).
//! * [`TreeRegistry`] / [`CompactTarget`] — type-erased handles to live
//!   trees for the background memory-management daemon ([`crate::mmd`]):
//!   registered trees expose their parent-patch entry points so the
//!   daemon can relocate (compact/rebalance) and evict/restore leaves
//!   through the forwarding machinery while views keep reading.
//! * [`TreeGeometry`] / [`TreeTraceModel`] — pure address arithmetic for
//!   the memsim experiments, so 64 GB arrays can be *modeled* without
//!   being materialized (§4.3's scales).

mod cursor;
mod layout;
pub(crate) mod registry;
mod tlb;
mod tree_array;
mod view;
mod write;

pub use cursor::Cursor;
pub use layout::{TreeGeometry, TreeTraceModel};
pub use registry::{CompactTarget, TreeRegistry};
pub use tlb::{LeafTlb, TlbStats};
pub use tree_array::{Pod, TreeArray};
pub use view::TreeView;
pub use write::TreeWriter;
