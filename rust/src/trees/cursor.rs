//! The Figure 2 iterator optimization, generalized: a cursor caching the
//! most recently used leaf *plus* a software leaf-TLB.
//!
//! Sequential `next()` is a bounds check + pointer bump; the full tree
//! walk happens only when iterating past a leaf's last element. Random
//! `seek()` probes the cached leaf first, then the [`LeafTlb`] — the
//! software analogue of a data TLB backed by a page-table-walk cache
//! (paper §4.4). Strided and random patterns that revisit leaves (GUPS,
//! hash probes, stencil sweeps) hit in the TLB where the bare Figure 2
//! cursor would re-walk on every access.
//!
//! The cursor snapshots the tree's relocation generation; every access
//! compares it and drops stale state on mismatch, so leaves migrated by
//! [`crate::pmem::Relocator`]-style relocation are re-resolved instead
//! of silently read at their freed location.

use crate::pmem::{BlockAlloc, BlockAllocator};
use crate::trees::tlb::{LeafTlb, TlbStats};
use crate::trees::tree_array::{Pod, TreeArray};

/// Cursor over a [`TreeArray`] with a cached leaf pointer and leaf-TLB.
pub struct Cursor<'t, 'a, T: Pod, A: BlockAlloc = BlockAllocator> {
    tree: &'t TreeArray<'a, T, A>,
    /// Cached leaf data pointer (null when unpositioned).
    leaf: *const T,
    /// First element index covered by the cached leaf.
    leaf_base: usize,
    /// One past the last element covered by the cached leaf.
    leaf_end: usize,
    /// Next element index for sequential iteration.
    pos: usize,
    /// Tree generation the cached state is valid for.
    gen: u64,
    /// Arena epoch last observed: moves *anywhere in the pool*
    /// ([`crate::pmem::Relocator`], [`crate::pmem::SwapPool`], foreign
    /// trees) flush the whole cache, not just this tree's generation.
    epoch_seen: u64,
    /// Second-level leaf cache (misses fall through to a full walk).
    tlb: LeafTlb,
    /// Leaf-cache statistics (hits = accesses served without a walk,
    /// from either the current leaf or the TLB).
    hits: u64,
    walks: u64,
}

impl<'t, 'a, T: Pod, A: BlockAlloc> Cursor<'t, 'a, T, A> {
    pub(crate) fn new(tree: &'t TreeArray<'a, T, A>) -> Self {
        Cursor::with_tlb(tree, LeafTlb::default_for_cursor())
    }

    pub(crate) fn with_tlb(tree: &'t TreeArray<'a, T, A>, tlb: LeafTlb) -> Self {
        Cursor {
            tree,
            leaf: std::ptr::null(),
            leaf_base: 0,
            leaf_end: 0,
            pos: 0,
            gen: tree.generation(),
            epoch_seen: tree.alloc.epoch().current(),
            tlb,
            hits: 0,
            walks: 0,
        }
    }

    /// Drop cached state when translation state moved under us — the
    /// shootdown check, two tiers:
    ///
    /// * **Arena epoch** (any relocation in the pool, including other
    ///   trees and raw [`crate::pmem::Relocator`] /
    ///   [`crate::pmem::SwapPool`] moves): flush everything — the
    ///   cursor cannot tell whether the moved block backs one of its
    ///   entries, so it assumes the worst, like a hardware TLB taking a
    ///   broadcast shootdown.
    /// * **Tree generation** (this tree's own leaves moved): drop the
    ///   current leaf; TLB entries carry their own generation stamps
    ///   and self-invalidate on lookup.
    ///
    /// Unlike [`crate::trees::TreeView`], a cursor does not register
    /// with the epoch: it is a same-thread companion, safe only under
    /// the immediate-free relocation contract
    /// ([`crate::trees::TreeArray::migrate_leaf_shared`]).
    #[inline]
    fn revalidate(&mut self) {
        let e = self.tree.alloc.epoch().current();
        if e != self.epoch_seen {
            self.epoch_seen = e;
            self.tlb.flush();
            self.leaf = std::ptr::null();
            self.leaf_base = 0;
            self.leaf_end = 0;
        }
        let g = self.tree.generation();
        if g != self.gen {
            self.gen = g;
            self.leaf = std::ptr::null();
            self.leaf_base = 0;
            self.leaf_end = 0;
        }
    }

    /// Make the cached leaf cover element `i`: TLB probe first (stays
    /// inline — leaf-bouncing patterns live here), full walk on miss.
    #[inline]
    fn repoint(&mut self, i: usize) {
        let leaf_idx = i / self.tree.geo.leaf_cap;
        if let Some((p, span)) = self.tlb.lookup(leaf_idx, self.gen) {
            self.leaf = p as *const T;
            self.leaf_base = leaf_idx * self.tree.geo.leaf_cap;
            self.leaf_end = self.leaf_base + span;
            self.hits += 1;
            return;
        }
        self.walk_fill(leaf_idx);
    }

    /// The rare full-walk path: translate `leaf_idx` through the tree
    /// and install the result in the cache levels.
    #[cold]
    fn walk_fill(&mut self, leaf_idx: usize) {
        let (p, span) = self.tree.leaf_ptr(leaf_idx);
        self.leaf = p as *const T;
        self.leaf_base = leaf_idx * self.tree.geo.leaf_cap;
        self.leaf_end = self.leaf_base + span;
        self.walks += 1;
        self.tlb.insert(leaf_idx, self.gen, p as *mut u8, span);
    }

    /// Read element `i`, probing the cached leaf, then the TLB.
    #[inline]
    pub fn seek(&mut self, i: usize) -> T {
        debug_assert!(i < self.tree.len());
        self.revalidate();
        if i < self.leaf_base || i >= self.leaf_end {
            self.repoint(i);
        } else {
            self.hits += 1;
        }
        // SAFETY: leaf covers [leaf_base, leaf_end) and i is inside.
        unsafe { self.leaf.add(i - self.leaf_base).read() }
    }

    /// (hits, walks) since creation — the leaf-cache effectiveness, the
    /// quantity Table 2's "Iter" rows hinge on. Hits count accesses
    /// served without a tree walk (current leaf *or* TLB).
    pub fn cache_stats(&self) -> (u64, u64) {
        (self.hits, self.walks)
    }

    /// Leaf-TLB counters (hits/misses/evictions/invalidations).
    pub fn tlb_stats(&self) -> TlbStats {
        self.tlb.stats()
    }

    /// Reset sequential position to `i` (next `next()` returns elem `i`).
    pub fn rewind(&mut self, i: usize) {
        self.pos = i;
    }
}

impl<T: Pod, A: BlockAlloc> Iterator for Cursor<'_, '_, T, A> {
    type Item = T;

    /// The paper's Figure 2 `next()`: bump within the cached leaf; walk
    /// only across leaf boundaries.
    #[inline]
    fn next(&mut self) -> Option<T> {
        if self.pos >= self.tree.len() {
            return None;
        }
        let i = self.pos;
        self.pos += 1;
        self.revalidate();
        if i >= self.leaf_end || i < self.leaf_base {
            self.repoint(i);
        } else {
            self.hits += 1;
        }
        // SAFETY: cached leaf covers i after repoint.
        Some(unsafe { self.leaf.add(i - self.leaf_base).read() })
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let rem = self.tree.len() - self.pos.min(self.tree.len());
        (rem, Some(rem))
    }
}

impl<T: Pod, A: BlockAlloc> ExactSizeIterator for Cursor<'_, '_, T, A> {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::BlockAllocator;
    use crate::testutil::forall;

    fn tree_with(n: usize) -> (BlockAllocator, Vec<u32>) {
        let a = BlockAllocator::new(1024, 1 << 14).unwrap();
        let data: Vec<u32> = (0..n as u32).map(|i| i.wrapping_mul(2654435761)).collect();
        (a, data)
    }

    #[test]
    fn sequential_iteration_matches() {
        let (a, data) = tree_with(256 * 30 + 11);
        let mut t: TreeArray<u32> = TreeArray::new(&a, data.len()).unwrap();
        t.copy_from_slice(&data).unwrap();
        let collected: Vec<u32> = t.iter().collect();
        assert_eq!(collected, data);
    }

    #[test]
    fn walks_once_per_leaf() {
        let (a, data) = tree_with(256 * 8);
        let mut t: TreeArray<u32> = TreeArray::new(&a, data.len()).unwrap();
        t.copy_from_slice(&data).unwrap();
        let mut c = t.iter();
        while c.next().is_some() {}
        let (hits, walks) = c.cache_stats();
        assert_eq!(walks, 8); // exactly one walk per leaf
        assert_eq!(hits, 256 * 8 - 8);
    }

    #[test]
    fn seek_same_leaf_hits_cache() {
        let (a, data) = tree_with(256 * 4);
        let mut t: TreeArray<u32> = TreeArray::new(&a, data.len()).unwrap();
        t.copy_from_slice(&data).unwrap();
        let mut c = t.cursor();
        assert_eq!(c.seek(10), data[10]); // walk
        assert_eq!(c.seek(20), data[20]); // same leaf: hit
        assert_eq!(c.seek(300), data[300]); // new leaf: walk
        let (hits, walks) = c.cache_stats();
        assert_eq!((hits, walks), (1, 2));
    }

    #[test]
    fn revisited_leaf_hits_tlb_not_walk() {
        // The headline TLB win: leaf 0 -> leaf 1 -> leaf 0 again. The
        // bare Figure 2 cursor walks 3 times; the TLB-backed cursor
        // serves the revisit from the TLB.
        let (a, data) = tree_with(256 * 4);
        let mut t: TreeArray<u32> = TreeArray::new(&a, data.len()).unwrap();
        t.copy_from_slice(&data).unwrap();
        let mut c = t.cursor();
        assert_eq!(c.seek(10), data[10]); // walk leaf 0
        assert_eq!(c.seek(300), data[300]); // walk leaf 1
        assert_eq!(c.seek(20), data[20]); // leaf 0 again: TLB hit
        let (hits, walks) = c.cache_stats();
        assert_eq!((hits, walks), (1, 2), "revisit must not re-walk");
        assert_eq!(c.tlb_stats().hits, 1);

        // And with the TLB disabled, the same pattern re-walks.
        let mut c0 = t.cursor_with_tlb(0, 1);
        c0.seek(10);
        c0.seek(300);
        c0.seek(20);
        assert_eq!(c0.cache_stats(), (0, 3), "bare cursor re-walks");
    }

    #[test]
    fn strided_leaf_bouncing_mostly_tlb_hits() {
        // Stride exactly one leaf: every access is a new leaf the first
        // lap, then laps 2..k are pure TLB hits.
        let (a, data) = tree_with(256 * 8);
        let mut t: TreeArray<u32> = TreeArray::new(&a, data.len()).unwrap();
        t.copy_from_slice(&data).unwrap();
        let mut c = t.cursor();
        for lap in 0..4 {
            let mut i = lap; // offset shifts to defeat the current-leaf cache
            while i < data.len() {
                assert_eq!(c.seek(i), data[i]);
                i += 256;
            }
        }
        let (_, walks) = c.cache_stats();
        assert_eq!(walks, 8, "only the first lap may walk");
        assert_eq!(c.tlb_stats().hits, 3 * 8);
    }

    #[test]
    fn rewind_restarts() {
        let (a, data) = tree_with(600);
        let mut t: TreeArray<u32> = TreeArray::new(&a, data.len()).unwrap();
        t.copy_from_slice(&data).unwrap();
        let mut c = t.iter();
        for _ in 0..500 {
            c.next();
        }
        c.rewind(0);
        assert_eq!(c.next(), Some(data[0]));
    }

    #[test]
    fn size_hint_exact() {
        let (a, data) = tree_with(100);
        let mut t: TreeArray<u32> = TreeArray::new(&a, 100).unwrap();
        t.copy_from_slice(&data).unwrap();
        let mut c = t.iter();
        assert_eq!(c.size_hint(), (100, Some(100)));
        c.next();
        assert_eq!(c.size_hint(), (99, Some(99)));
    }

    #[test]
    fn prop_seek_equals_get() {
        forall(30, |g| {
            let a = BlockAllocator::new(1024, 1 << 14).unwrap();
            let n = g.usize_in(1, 256 * 100);
            let mut t: TreeArray<u32> = TreeArray::new(&a, n).unwrap();
            let data: Vec<u32> = (0..n).map(|_| g.rng().next_u32()).collect();
            t.copy_from_slice(&data).unwrap();
            let mut c = t.cursor();
            for _ in 0..100 {
                let i = g.usize_in(0, n - 1);
                assert_eq!(c.seek(i), t.get(i).unwrap());
            }
        });
    }

    #[test]
    fn prop_strided_iteration_matches() {
        forall(20, |g| {
            let a = BlockAllocator::new(1024, 1 << 14).unwrap();
            let n = g.usize_in(2, 256 * 64);
            let stride = g.usize_in(1, 1024);
            let mut t: TreeArray<u32> = TreeArray::new(&a, n).unwrap();
            let data: Vec<u32> = (0..n).map(|_| g.rng().next_u32()).collect();
            t.copy_from_slice(&data).unwrap();
            let mut c = t.cursor();
            let mut i = 0usize;
            while i < n {
                assert_eq!(c.seek(i), data[i]);
                i += stride;
            }
        });
    }

    #[test]
    fn seek_revalidates_after_relocation() {
        // Unit-level shootdown check (the allocator-reuse scenario lives
        // in tests/translation.rs): cursor caches a leaf, the leaf
        // migrates, the next seek must re-resolve, not reuse the stale
        // pointer.
        let (a, data) = tree_with(256 * 4);
        let mut t: TreeArray<u32> = TreeArray::new(&a, data.len()).unwrap();
        t.copy_from_slice(&data).unwrap();
        let mut c = t.cursor();
        assert_eq!(c.seek(10), data[10]);
        let gen0 = t.generation();
        // SAFETY: only the revalidating cursor observes the tree.
        unsafe { t.migrate_leaf_shared(0) }.unwrap();
        assert_eq!(t.generation(), gen0 + 1);
        assert_eq!(c.seek(10), data[10], "stale read after relocate");
        let (_, walks) = c.cache_stats();
        assert_eq!(walks, 2, "revalidation must force a fresh walk");
        assert!(c.tlb_stats().invalidations >= 1, "TLB entry must self-invalidate");
    }
}
