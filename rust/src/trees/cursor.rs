//! The Figure 2 iterator optimization: a cursor caching the most
//! recently used leaf.
//!
//! Sequential `next()` is a bounds check + pointer bump; the full tree
//! walk happens only when iterating past a leaf's last element. Random
//! `seek()` probes the cached leaf first — the software analogue of a
//! page-table-walk cache (paper §4.4).

use crate::pmem::{BlockAlloc, BlockAllocator};
use crate::trees::tree_array::{Pod, TreeArray};

/// Cursor over a [`TreeArray`] with a cached leaf pointer.
pub struct Cursor<'t, 'a, T: Pod, A: BlockAlloc = BlockAllocator> {
    tree: &'t TreeArray<'a, T, A>,
    /// Cached leaf data pointer (null when unpositioned).
    leaf: *const T,
    /// First element index covered by the cached leaf.
    leaf_base: usize,
    /// One past the last element covered by the cached leaf.
    leaf_end: usize,
    /// Next element index for sequential iteration.
    pos: usize,
    /// Leaf-cache statistics (hits = accesses served without a walk).
    hits: u64,
    walks: u64,
}

impl<'t, 'a, T: Pod, A: BlockAlloc> Cursor<'t, 'a, T, A> {
    pub(crate) fn new(tree: &'t TreeArray<'a, T, A>) -> Self {
        Cursor {
            tree,
            leaf: std::ptr::null(),
            leaf_base: 0,
            leaf_end: 0,
            pos: 0,
            hits: 0,
            walks: 0,
        }
    }

    /// Refill the leaf cache for the leaf containing `i` (a full walk).
    #[cold]
    fn refill(&mut self, i: usize) {
        let leaf_idx = i / self.tree.geo.leaf_cap;
        let (p, span) = self.tree.leaf_ptr(leaf_idx);
        self.leaf = p as *const T;
        self.leaf_base = leaf_idx * self.tree.geo.leaf_cap;
        self.leaf_end = self.leaf_base + span;
        self.walks += 1;
    }

    /// Read element `i`, probing the cached leaf first.
    #[inline]
    pub fn seek(&mut self, i: usize) -> T {
        debug_assert!(i < self.tree.len());
        if i < self.leaf_base || i >= self.leaf_end {
            self.refill(i);
        } else {
            self.hits += 1;
        }
        // SAFETY: leaf covers [leaf_base, leaf_end) and i is inside.
        unsafe { self.leaf.add(i - self.leaf_base).read() }
    }

    /// (hits, walks) since creation — the leaf-cache effectiveness, the
    /// quantity Table 2's "Iter" rows hinge on.
    pub fn cache_stats(&self) -> (u64, u64) {
        (self.hits, self.walks)
    }

    /// Reset sequential position to `i` (next `next()` returns elem `i`).
    pub fn rewind(&mut self, i: usize) {
        self.pos = i;
    }
}

impl<T: Pod, A: BlockAlloc> Iterator for Cursor<'_, '_, T, A> {
    type Item = T;

    /// The paper's Figure 2 `next()`: bump within the cached leaf; walk
    /// only across leaf boundaries.
    #[inline]
    fn next(&mut self) -> Option<T> {
        if self.pos >= self.tree.len() {
            return None;
        }
        let i = self.pos;
        self.pos += 1;
        if i >= self.leaf_end || i < self.leaf_base {
            self.refill(i);
        } else {
            self.hits += 1;
        }
        // SAFETY: cached leaf covers i after refill.
        Some(unsafe { self.leaf.add(i - self.leaf_base).read() })
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let rem = self.tree.len() - self.pos.min(self.tree.len());
        (rem, Some(rem))
    }
}

impl<T: Pod, A: BlockAlloc> ExactSizeIterator for Cursor<'_, '_, T, A> {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::BlockAllocator;
    use crate::testutil::forall;

    fn tree_with(n: usize) -> (BlockAllocator, Vec<u32>) {
        let a = BlockAllocator::new(1024, 1 << 14).unwrap();
        let data: Vec<u32> = (0..n as u32).map(|i| i.wrapping_mul(2654435761)).collect();
        (a, data)
    }

    #[test]
    fn sequential_iteration_matches() {
        let (a, data) = tree_with(256 * 30 + 11);
        let mut t: TreeArray<u32> = TreeArray::new(&a, data.len()).unwrap();
        t.copy_from_slice(&data).unwrap();
        let collected: Vec<u32> = t.iter().collect();
        assert_eq!(collected, data);
    }

    #[test]
    fn walks_once_per_leaf() {
        let (a, data) = tree_with(256 * 8);
        let mut t: TreeArray<u32> = TreeArray::new(&a, data.len()).unwrap();
        t.copy_from_slice(&data).unwrap();
        let mut c = t.iter();
        while c.next().is_some() {}
        let (hits, walks) = c.cache_stats();
        assert_eq!(walks, 8); // exactly one walk per leaf
        assert_eq!(hits, 256 * 8 - 8);
    }

    #[test]
    fn seek_same_leaf_hits_cache() {
        let (a, data) = tree_with(256 * 4);
        let mut t: TreeArray<u32> = TreeArray::new(&a, data.len()).unwrap();
        t.copy_from_slice(&data).unwrap();
        let mut c = t.cursor();
        assert_eq!(c.seek(10), data[10]); // walk
        assert_eq!(c.seek(20), data[20]); // same leaf: hit
        assert_eq!(c.seek(300), data[300]); // new leaf: walk
        let (hits, walks) = c.cache_stats();
        assert_eq!((hits, walks), (1, 2));
    }

    #[test]
    fn rewind_restarts() {
        let (a, data) = tree_with(600);
        let mut t: TreeArray<u32> = TreeArray::new(&a, data.len()).unwrap();
        t.copy_from_slice(&data).unwrap();
        let mut c = t.iter();
        for _ in 0..500 {
            c.next();
        }
        c.rewind(0);
        assert_eq!(c.next(), Some(data[0]));
    }

    #[test]
    fn size_hint_exact() {
        let (a, data) = tree_with(100);
        let mut t: TreeArray<u32> = TreeArray::new(&a, 100).unwrap();
        t.copy_from_slice(&data).unwrap();
        let mut c = t.iter();
        assert_eq!(c.size_hint(), (100, Some(100)));
        c.next();
        assert_eq!(c.size_hint(), (99, Some(99)));
    }

    #[test]
    fn prop_seek_equals_get() {
        forall(30, |g| {
            let a = BlockAllocator::new(1024, 1 << 14).unwrap();
            let n = g.usize_in(1, 256 * 100);
            let mut t: TreeArray<u32> = TreeArray::new(&a, n).unwrap();
            let data: Vec<u32> = (0..n).map(|_| g.rng().next_u32()).collect();
            t.copy_from_slice(&data).unwrap();
            let mut c = t.cursor();
            for _ in 0..100 {
                let i = g.usize_in(0, n - 1);
                assert_eq!(c.seek(i), t.get(i).unwrap());
            }
        });
    }

    #[test]
    fn prop_strided_iteration_matches() {
        forall(20, |g| {
            let a = BlockAllocator::new(1024, 1 << 14).unwrap();
            let n = g.usize_in(2, 256 * 64);
            let stride = g.usize_in(1, 1024);
            let mut t: TreeArray<u32> = TreeArray::new(&a, n).unwrap();
            let data: Vec<u32> = (0..n).map(|_| g.rng().next_u32()).collect();
            t.copy_from_slice(&data).unwrap();
            let mut c = t.cursor();
            let mut i = 0usize;
            while i < n {
                assert_eq!(c.seek(i), data[i]);
                i += stride;
            }
        });
    }
}
