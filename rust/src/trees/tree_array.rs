//! The arrays-as-trees data structure over allocator blocks.

use crate::error::{Error, Result};
use crate::pmem::{BlockAlloc, BlockAllocator, BlockId};
use crate::trees::layout::TreeGeometry;
use crate::trees::Cursor;

/// Plain-old-data element types storable in tree leaves.
///
/// # Safety
/// Implementors must be valid for any bit pattern and contain no padding
/// (they are memcpy'd in and out of raw blocks).
pub unsafe trait Pod: Copy + Default + PartialEq + std::fmt::Debug + 'static {}

unsafe impl Pod for u8 {}
unsafe impl Pod for u16 {}
unsafe impl Pod for u32 {}
unsafe impl Pod for u64 {}
unsafe impl Pod for i8 {}
unsafe impl Pod for i16 {}
unsafe impl Pod for i32 {}
unsafe impl Pod for i64 {}
unsafe impl Pod for f32 {}
unsafe impl Pod for f64 {}
unsafe impl Pod for usize {}

/// A fixed-length array of `T` stored as a tree of fixed-size blocks
/// (paper §3.2 / Figure 1). Interior nodes hold 8-byte child block ids;
/// leaves hold element data. Depth is 1–4 and recorded as metadata, per
/// the paper ("a tree stores meta-data about its depth").
///
/// Generic over the allocator policy `A` (defaulting to the mutex
/// baseline), so the same tree runs over [`BlockAllocator`] and
/// [`crate::pmem::ShardedAllocator`] unchanged.
pub struct TreeArray<'a, T: Pod, A: BlockAlloc = BlockAllocator> {
    pub(crate) alloc: &'a A,
    pub(crate) geo: TreeGeometry,
    root: BlockId,
    blocks: Vec<BlockId>, // all blocks, for Drop
    _t: std::marker::PhantomData<T>,
}

impl<'a, T: Pod, A: BlockAlloc> TreeArray<'a, T, A> {
    /// Allocate a zeroed tree array of `len` elements using the paper's
    /// geometry (node size = allocator block size, 8-byte child ids).
    pub fn new(alloc: &'a A, len: usize) -> Result<Self> {
        let geo = TreeGeometry::new(alloc.block_size(), std::mem::size_of::<T>(), len)?;
        // Build bottom-up: leaves first, then interior levels.
        let nleaves = geo.nleaves();
        let mut all = Vec::with_capacity(geo.total_blocks());
        let mut level: Vec<BlockId> = alloc.alloc_many(nleaves)?;
        // The allocator only guarantees zero contents on a block's FIRST
        // use; recycled blocks carry stale data. The constructor promises
        // a zeroed array, so scrub the leaves explicitly.
        for leaf in &level {
            // SAFETY: leaf is live and exclusively ours.
            unsafe { std::ptr::write_bytes(alloc.block_ptr(*leaf), 0, alloc.block_size()) };
        }
        all.extend_from_slice(&level);
        let mut depth_built = 1;
        while level.len() > 1 || depth_built < geo.depth {
            let nparents = level.len().div_ceil(geo.fanout);
            let parents = match alloc.alloc_many(nparents) {
                Ok(p) => p,
                Err(e) => {
                    for b in &all {
                        let _ = alloc.free(*b);
                    }
                    return Err(e);
                }
            };
            for (pi, parent) in parents.iter().enumerate() {
                let lo = pi * geo.fanout;
                let hi = ((pi + 1) * geo.fanout).min(level.len());
                for (slot, child) in level[lo..hi].iter().enumerate() {
                    let id64 = child.0 as u64;
                    alloc.write(*parent, slot * 8, &id64.to_le_bytes())?;
                }
            }
            all.extend_from_slice(&parents);
            level = parents;
            depth_built += 1;
        }
        debug_assert_eq!(depth_built, geo.depth);
        Ok(TreeArray {
            alloc,
            geo,
            root: level[0],
            blocks: all,
            _t: std::marker::PhantomData,
        })
    }

    /// Element count.
    #[inline]
    pub fn len(&self) -> usize {
        self.geo.len
    }

    /// True when the array holds no elements.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.geo.len == 0
    }

    /// Tree depth (1 = single leaf).
    #[inline]
    pub fn depth(&self) -> u32 {
        self.geo.depth
    }

    /// Geometry metadata.
    #[inline]
    pub fn geometry(&self) -> TreeGeometry {
        self.geo
    }

    /// Walk from the root to the leaf holding element `i`.
    /// This is the *naive* access of Table 2: `depth` dependent loads.
    #[inline]
    fn walk_to_leaf(&self, i: usize) -> BlockId {
        let mut node = self.root;
        for level in 0..self.geo.depth - 1 {
            let slot = self.geo.child_slot(level, i);
            let mut buf = [0u8; 8];
            // SAFETY: node is one of our live blocks; slot < fanout.
            unsafe {
                let p = self.alloc.block_ptr(node).add(slot * 8);
                std::ptr::copy_nonoverlapping(p, buf.as_mut_ptr(), 8);
            }
            node = BlockId(u64::from_le_bytes(buf) as u32);
        }
        node
    }

    /// Read element `i` (naive tree walk, bounds-checked).
    pub fn get(&self, i: usize) -> Result<T> {
        if i >= self.geo.len {
            return Err(Error::IndexOutOfBounds {
                index: i,
                len: self.geo.len,
            });
        }
        Ok(unsafe { self.get_unchecked(i) })
    }

    /// Read element `i` without bounds checking.
    ///
    /// # Safety
    /// `i < self.len()`.
    #[inline]
    pub unsafe fn get_unchecked(&self, i: usize) -> T {
        let leaf = self.walk_to_leaf(i);
        let off = (i % self.geo.leaf_cap) * std::mem::size_of::<T>();
        let p = self.alloc.block_ptr(leaf).add(off) as *const T;
        p.read_unaligned()
    }

    /// Write element `i` (naive tree walk, bounds-checked).
    pub fn set(&mut self, i: usize, v: T) -> Result<()> {
        if i >= self.geo.len {
            return Err(Error::IndexOutOfBounds {
                index: i,
                len: self.geo.len,
            });
        }
        unsafe { self.set_unchecked(i, v) };
        Ok(())
    }

    /// Write element `i` without bounds checking.
    ///
    /// # Safety
    /// `i < self.len()`.
    #[inline]
    pub unsafe fn set_unchecked(&mut self, i: usize, v: T) {
        let leaf = self.walk_to_leaf(i);
        let off = (i % self.geo.leaf_cap) * std::mem::size_of::<T>();
        let p = self.alloc.block_ptr(leaf).add(off) as *mut T;
        p.write_unaligned(v);
    }

    /// Raw leaf pointer + element span for leaf `leaf_idx`
    /// (crate-internal: powers [`Cursor`] and the leaf slices).
    #[inline]
    pub(crate) fn leaf_ptr(&self, leaf_idx: usize) -> (*mut T, usize) {
        let first_elem = leaf_idx * self.geo.leaf_cap;
        let leaf = self.walk_to_leaf(first_elem);
        let span = self.geo.leaf_cap.min(self.geo.len - first_elem);
        // SAFETY: leaf is live; pointer valid for leaf_cap elements.
        (unsafe { self.alloc.block_ptr(leaf) as *mut T }, span)
    }

    /// Borrow leaf `leaf_idx`'s elements as a slice (zero-copy: this is
    /// the exact 32 KB buffer the Pallas blocked kernel consumes).
    pub fn leaf_slice(&self, leaf_idx: usize) -> &[T] {
        assert!(leaf_idx < self.geo.nleaves());
        let (p, span) = self.leaf_ptr(leaf_idx);
        // SAFETY: p valid for span elements; &self borrow prevents writes
        // through the safe API for the slice's lifetime.
        unsafe { std::slice::from_raw_parts(p, span) }
    }

    /// Mutably borrow leaf `leaf_idx`'s elements.
    pub fn leaf_slice_mut(&mut self, leaf_idx: usize) -> &mut [T] {
        assert!(leaf_idx < self.geo.nleaves());
        let (p, span) = self.leaf_ptr(leaf_idx);
        // SAFETY: as above, with exclusive borrow.
        unsafe { std::slice::from_raw_parts_mut(p, span) }
    }

    /// Number of leaf blocks.
    #[inline]
    pub fn nleaves(&self) -> usize {
        self.geo.nleaves()
    }

    /// Bulk-load from a slice (leaf-at-a-time memcpy).
    pub fn copy_from_slice(&mut self, src: &[T]) -> Result<()> {
        if src.len() != self.geo.len {
            return Err(Error::IndexOutOfBounds {
                index: src.len(),
                len: self.geo.len,
            });
        }
        let cap = self.geo.leaf_cap;
        for leaf in 0..self.nleaves() {
            let lo = leaf * cap;
            let hi = (lo + cap).min(src.len());
            self.leaf_slice_mut(leaf)[..hi - lo].copy_from_slice(&src[lo..hi]);
        }
        Ok(())
    }

    /// Copy out to a `Vec` (for verification against contiguous baselines).
    pub fn to_vec(&self) -> Vec<T> {
        let mut out = Vec::with_capacity(self.geo.len);
        for leaf in 0..self.nleaves() {
            out.extend_from_slice(self.leaf_slice(leaf));
        }
        out
    }

    /// Relocate one leaf to a fresh block, patching the single parent
    /// pointer (or the root). See `pmem::migrate` for the public API
    /// and the paper-§2 relocation story.
    pub(crate) fn relocate_leaf_impl(&mut self, leaf_idx: usize) -> Result<BlockId> {
        let first_elem = leaf_idx * self.geo.leaf_cap;
        // Walk down recording the parent slot that names the leaf.
        let mut node = self.root;
        let mut parent: Option<(BlockId, usize)> = None;
        for level in 0..self.geo.depth - 1 {
            let slot = self.geo.child_slot(level, first_elem);
            let mut buf = [0u8; 8];
            // SAFETY: node is one of our live blocks; slot < fanout.
            unsafe {
                let p = self.alloc.block_ptr(node).add(slot * 8);
                std::ptr::copy_nonoverlapping(p, buf.as_mut_ptr(), 8);
            }
            parent = Some((node, slot));
            node = BlockId(u64::from_le_bytes(buf) as u32);
        }
        let old = node;
        let fresh = self.alloc.alloc()?;
        let bs = self.alloc.block_size();
        // SAFETY: both blocks live and distinct; full-block copy.
        unsafe {
            std::ptr::copy_nonoverlapping(self.alloc.block_ptr(old), self.alloc.block_ptr(fresh), bs);
        }
        match parent {
            Some((p, slot)) => {
                self.alloc
                    .write(p, slot * 8, &(fresh.0 as u64).to_le_bytes())?;
            }
            None => self.root = fresh, // depth-1: the leaf is the root
        }
        self.alloc.free(old)?;
        if let Some(pos) = self.blocks.iter().position(|b| *b == old) {
            self.blocks[pos] = fresh;
        }
        Ok(fresh)
    }

    /// Sequential iterator using the Figure 2 cached-leaf optimization.
    pub fn iter(&self) -> Cursor<'_, 'a, T, A> {
        Cursor::new(self)
    }

    /// A random-access cursor starting unpositioned (leaf cache empty).
    pub fn cursor(&self) -> Cursor<'_, 'a, T, A> {
        Cursor::new(self)
    }
}

impl<T: Pod, A: BlockAlloc> Drop for TreeArray<'_, T, A> {
    fn drop(&mut self) {
        for b in &self.blocks {
            let _ = self.alloc.free(*b);
        }
    }
}

impl<T: Pod, A: BlockAlloc> std::fmt::Debug for TreeArray<'_, T, A> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "TreeArray {{ len: {}, depth: {}, leaves: {} }}",
            self.geo.len,
            self.geo.depth,
            self.nleaves()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{forall, Rng};

    fn small_alloc() -> BlockAllocator {
        // 1 KB blocks keep trees deep at tiny sizes: leaf_cap(f32)=256,
        // fanout=128.
        BlockAllocator::new(1024, 4096).unwrap()
    }

    #[test]
    fn depth1_roundtrip() {
        let a = small_alloc();
        let mut t: TreeArray<f32> = TreeArray::new(&a, 100).unwrap();
        assert_eq!(t.depth(), 1);
        for i in 0..100 {
            t.set(i, i as f32).unwrap();
        }
        for i in 0..100 {
            assert_eq!(t.get(i).unwrap(), i as f32);
        }
    }

    #[test]
    fn depth2_roundtrip() {
        let a = small_alloc();
        let n = 256 * 60; // 60 leaves -> depth 2
        let mut t: TreeArray<f32> = TreeArray::new(&a, n).unwrap();
        assert_eq!(t.depth(), 2);
        for i in (0..n).step_by(7) {
            t.set(i, (i * 3) as f32).unwrap();
        }
        for i in (0..n).step_by(7) {
            assert_eq!(t.get(i).unwrap(), (i * 3) as f32);
        }
    }

    #[test]
    fn depth3_roundtrip() {
        let a = BlockAllocator::new(1024, 1 << 16).unwrap();
        let n = 256 * 128 * 3 + 17; // > fanout leaves -> depth 3
        let mut t: TreeArray<u32> = TreeArray::new(&a, n).unwrap();
        assert_eq!(t.depth(), 3);
        let idxs = [0usize, 1, 255, 256, 32767, 32768, n - 1];
        for &i in &idxs {
            t.set(i, i as u32 ^ 0xDEAD).unwrap();
        }
        for &i in &idxs {
            assert_eq!(t.get(i).unwrap(), i as u32 ^ 0xDEAD);
        }
    }

    #[test]
    fn oob_get_set_rejected() {
        let a = small_alloc();
        let mut t: TreeArray<u8> = TreeArray::new(&a, 10).unwrap();
        assert!(t.get(10).is_err());
        assert!(t.set(10, 0).is_err());
    }

    #[test]
    fn zero_initialized() {
        let a = small_alloc();
        let t: TreeArray<u64> = TreeArray::new(&a, 1000).unwrap();
        assert!(t.iter().all(|v| v == 0));
    }

    #[test]
    fn zero_initialized_even_on_recycled_blocks() {
        // Blocks freed by a dropped tree carry stale data; a new tree
        // over the same pool must still read all-zero.
        let a = small_alloc();
        {
            let mut t: TreeArray<u64> = TreeArray::new(&a, 1000).unwrap();
            for i in 0..1000 {
                t.set(i, 0xDEAD_BEEF).unwrap();
            }
        }
        let t2: TreeArray<u64> = TreeArray::new(&a, 1000).unwrap();
        assert!(t2.iter().all(|v| v == 0), "recycled leaves not scrubbed");
    }

    #[test]
    fn blocks_freed_on_drop() {
        let a = small_alloc();
        {
            let _t: TreeArray<f32> = TreeArray::new(&a, 256 * 60).unwrap();
            assert!(a.stats().allocated > 60); // leaves + root
        }
        assert_eq!(a.stats().allocated, 0);
    }

    #[test]
    fn alloc_failure_leaks_nothing() {
        let a = BlockAllocator::new(1024, 32).unwrap();
        // 60 leaves needed but only 32 blocks available.
        assert!(TreeArray::<f32>::new(&a, 256 * 60).is_err());
        assert_eq!(a.stats().allocated, 0);
    }

    #[test]
    fn copy_from_slice_to_vec_roundtrip() {
        let a = small_alloc();
        let n = 256 * 10 + 13;
        let mut t: TreeArray<f32> = TreeArray::new(&a, n).unwrap();
        let src: Vec<f32> = (0..n).map(|i| i as f32 * 0.5).collect();
        t.copy_from_slice(&src).unwrap();
        assert_eq!(t.to_vec(), src);
    }

    #[test]
    fn leaf_slice_matches_elements() {
        let a = small_alloc();
        let n = 256 * 3 + 40; // 4 leaves, last partial
        let mut t: TreeArray<u32> = TreeArray::new(&a, n).unwrap();
        for i in 0..n {
            t.set(i, i as u32).unwrap();
        }
        assert_eq!(t.leaf_slice(0).len(), 256);
        assert_eq!(t.leaf_slice(3).len(), 40);
        assert_eq!(t.leaf_slice(1)[5], 256 + 5);
    }

    #[test]
    fn prop_tree_matches_vec_model() {
        forall(30, |g| {
            let a = BlockAllocator::new(1024, 1 << 14).unwrap();
            let n = g.usize_in(1, 256 * 200);
            let mut t: TreeArray<u32> = TreeArray::new(&a, n).unwrap();
            let mut model = vec![0u32; n];
            for _ in 0..g.usize_in(1, 300) {
                let i = g.usize_in(0, n - 1);
                let v = g.rng().next_u32();
                t.set(i, v).unwrap();
                model[i] = v;
            }
            // Spot-check random reads + full to_vec.
            for _ in 0..50 {
                let i = g.usize_in(0, n - 1);
                assert_eq!(t.get(i).unwrap(), model[i]);
            }
            assert_eq!(t.to_vec(), model);
        });
    }

    #[test]
    fn prop_paper_block_size_geometry() {
        // With real 32 KB blocks: 4 KB fits depth 1, 4 MB depth 2.
        let a = BlockAllocator::new(32 * 1024, 512).unwrap();
        let t1: TreeArray<f32> = TreeArray::new(&a, 1024).unwrap(); // 4 KB
        assert_eq!(t1.depth(), 1);
        let t2: TreeArray<f32> = TreeArray::new(&a, 1 << 20).unwrap(); // 4 MB
        assert_eq!(t2.depth(), 2);
    }

    #[test]
    fn large_u8_tree() {
        let a = small_alloc();
        let n = 1024 * 130; // u8: leaf_cap 1024, fanout 128 -> depth 3
        let mut t: TreeArray<u8> = TreeArray::new(&a, n).unwrap();
        assert_eq!(t.depth(), 3);
        let mut rng = Rng::new(5);
        let mut pairs = Vec::new();
        for _ in 0..200 {
            let i = rng.range(0, n);
            let v = rng.next_u32() as u8;
            t.set(i, v).unwrap();
            pairs.push((i, v));
        }
        // last write wins per index
        let mut expect = std::collections::HashMap::new();
        for (i, v) in pairs {
            expect.insert(i, v);
        }
        for (i, v) in expect {
            assert_eq!(t.get(i).unwrap(), v);
        }
    }
}
