//! The arrays-as-trees data structure over allocator blocks.

use std::sync::atomic::{AtomicBool, AtomicPtr, AtomicU32, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::error::{Error, Result};
use crate::pmem::faultq::{LeafFaulter, SwapService};
use crate::pmem::swap::SwapSlot;
use crate::pmem::{BlockAlloc, BlockAllocator, BlockId};
use crate::trees::layout::TreeGeometry;
use crate::trees::tlb::LeafTlb;
use crate::trees::view::TreeView;
use crate::trees::write::TreeWriter;
use crate::trees::Cursor;

/// Plain-old-data element types storable in tree leaves.
///
/// # Safety
/// Implementors must be valid for any bit pattern and contain no padding
/// (they are memcpy'd in and out of raw blocks). The element size must be
/// a power of two ([`TreeGeometry`] enforces this at construction), which
/// together with the arena's block alignment guarantees *aligned* element
/// access: blocks start at addresses aligned to `block_size` (the arena
/// allocates with `Layout::from_size_align(_, block_size)`), every element
/// sits at a multiple of `size_of::<T>()` inside its block, and Rust
/// guarantees `size_of::<T>()` is a multiple of `align_of::<T>()` — so all
/// element pointers are aligned and plain `read`/`write` (not the
/// `_unaligned` variants) are sound everywhere in this module.
pub unsafe trait Pod: Copy + Default + PartialEq + std::fmt::Debug + 'static {}

unsafe impl Pod for u8 {}
unsafe impl Pod for u16 {}
unsafe impl Pod for u32 {}
unsafe impl Pod for u64 {}
unsafe impl Pod for i8 {}
unsafe impl Pod for i16 {}
unsafe impl Pod for i32 {}
unsafe impl Pod for i64 {}
unsafe impl Pod for f32 {}
unsafe impl Pod for f64 {}
unsafe impl Pod for usize {}

/// A fixed-length array of `T` stored as a tree of fixed-size blocks
/// (paper §3.2 / Figure 1). Interior nodes hold 8-byte child block ids;
/// leaves hold element data. Depth is 1–4 and recorded as metadata, per
/// the paper ("a tree stores meta-data about its depth").
///
/// Generic over the allocator policy `A` (defaulting to the mutex
/// baseline), so the same tree runs over [`BlockAllocator`] and
/// [`crate::pmem::ShardedAllocator`] unchanged.
///
/// # Translation (paper §4.4)
///
/// Three ways to turn an element index into a leaf location, in
/// increasing order of software-TLB sophistication:
///
/// 1. **Naive walk** — `depth` dependent loads (Table 2's baseline).
/// 2. **Cursor** ([`TreeArray::cursor`]) — a single cached leaf plus a
///    set-associative [`LeafTlb`]; random re-visits hit in O(1).
/// 3. **Flat leaf table** ([`TreeArray::enable_flat_table`]) — one
///    pointer per leaf, built lazily at first translated access; every
///    translation becomes a single indexed load. Translation metadata is
///    tiny relative to data (one 8-byte pointer per 32 KB leaf ≈ 0.02%),
///    which is why flattening it wholesale is affordable.
///
/// # Relocation and the generation counter
///
/// [`TreeArray::migrate_leaf`] moves a leaf to a fresh block. The
/// root/leaf bookkeeping is interior-mutable (atomics) so a leaf can
/// move *while cursors are live* — that shared-access form is the
/// `unsafe` [`TreeArray::migrate_leaf_shared`] (`&self`), whose caller
/// vouches that no raw leaf slice pins the moving leaf's old location;
/// the safe `migrate_leaf` takes `&mut self` so the borrow checker
/// proves it. Every relocation bumps the tree's generation; cursors and
/// TLB entries are stamped with the generation at fill time and
/// revalidate on mismatch (the software shootdown protocol — without it
/// a cursor would silently read the freed block). Relocation requires
/// external synchronization with respect to accessors in *other
/// threads* (same single-writer contract as [`BlockAlloc::block_ptr`]);
/// the generation protocol makes same-thread interleavings of relocate
/// and cached reads safe.
///
/// # Writers and the per-leaf seqlocks
///
/// Every leaf carries an atomic **sequence word** (`seq`): even = leaf
/// stable, odd = a write or relocation is in flight. Three parties run
/// the protocol:
///
/// * [`TreeWriter`] (created by the `unsafe`
///   [`TreeArray::writer`]) acquires a leaf's seqlock (CAS even →
///   odd), re-validates its translation under the lock, writes, and
///   releases (store odd + 1). Writers to *different* leaves never
///   contend; writers to the same leaf serialize on the CAS.
/// * [`TreeView`] readers sandwich each leaf read between two sequence
///   loads and retry on an odd or changed value, so a torn or mid-write
///   read is never returned.
/// * `migrate_leaf*` relocation acquires the seqlock before copying, so
///   a leaf is never simultaneously written and moved — the copy cannot
///   tear a write, and a writer acquiring after the move re-translates
///   (the generation bump happens inside the locked section).
///
/// # Software page faults
///
/// A fourth party joins the seqlock protocol when a tree is registered
/// evictable: [`TreeArray::evict_leaf_via`] pushes a cold leaf's bytes
/// to swap and records the slot in the leaf's *swap word* without
/// touching any translation pointer, and accessors check that word
/// inside their seq brackets — a hit diverts to the fault hook
/// ([`TreeArray::fault_leaf`]), which re-reads the payload through the
/// installed [`LeafFaulter`] and adopts the fresh block *under the
/// leaf's seqlock*, so concurrent readers retry rather than observe a
/// half-restored leaf and duplicate faults serialize into one I/O.
/// There is no hardware fault handler anywhere in this path — the
/// paper's premise made mechanism: detection is two loads in the read
/// bracket, and resolution is ordinary library code.
pub struct TreeArray<'a, T: Pod, A: BlockAlloc = BlockAllocator> {
    pub(crate) alloc: &'a A,
    pub(crate) geo: TreeGeometry,
    /// Root block id (atomic: depth-1 relocation replaces the root).
    root: AtomicU32,
    /// All blocks for Drop, *leaves first in leaf order*: `blocks[l]` is
    /// leaf `l`'s current block for `l < nleaves()` (the invariant that
    /// makes relocation bookkeeping and the flat table O(1)).
    blocks: Box<[AtomicU32]>,
    /// Bumped on every leaf relocation; translation caches revalidate on
    /// mismatch. See the type-level docs.
    generation: AtomicU64,
    /// Flat leaf-table mode switch.
    flat_on: AtomicBool,
    /// Lazily built leaf-pointer table (one `*mut u8` per leaf).
    flat: OnceLock<Box<[AtomicPtr<u8>]>>,
    /// Per-leaf write sequence words (seqlocks): odd = a writer or a
    /// relocation holds the leaf. See the type-level "Writers" docs.
    seq: Box<[AtomicU64]>,
    /// Per-leaf swap state: [`SWAP_RESIDENT`] = the leaf's bytes are in
    /// memory; anything else is the raw [`SwapSlot`] holding them. A
    /// swap word only changes under the leaf's seqlock, and eviction
    /// deliberately does **not** change the leaf's translation — the
    /// parent slot / `blocks` entry / flat table keep naming the
    /// retired block, and the swapped check inside every seq bracket is
    /// what keeps accessors off it (see the "Software page faults"
    /// type-level docs).
    swap_words: Box<[AtomicU64]>,
    /// Per-leaf last-touch tick (coarse access recency): stamped from
    /// `touch_clock` on every translation miss and fault-in, read by
    /// the mmd eviction policy to pick genuinely cold victims. Relaxed
    /// everywhere — a slightly stale tick only costs victim quality.
    touch: Box<[AtomicU64]>,
    /// Global tick source for `touch`.
    touch_clock: AtomicU64,
    /// Total seqlock acquisition attempts lost to contention across all
    /// leaves (writers, relocations, fault-ins). The mmd policy reads
    /// the per-tick delta as writer-heat and defers compaction.
    lock_waits_total: AtomicU64,
    /// Total read-side seq-bracket retries across all views of this
    /// tree (reader pain: a retry means a writer or a relocation
    /// overlapped a read). The mmd policy reads the per-tick delta and
    /// backs compaction off when readers are hurting.
    seq_retries_total: AtomicU64,
    /// The installed fault handler, if any (type-erased; see
    /// [`TreeArray::install_faulter`]). Locked only on the fault path.
    faulter: Mutex<Option<FaulterPtr>>,
    _t: std::marker::PhantomData<T>,
}

/// The sentinel a swap word holds while the leaf is resident (slot
/// indices start at 0, so the all-ones pattern can never be a slot).
pub(crate) const SWAP_RESIDENT: u64 = u64::MAX;

/// A type-erased, lifetime-erased pointer to the installed
/// [`LeafFaulter`]. The erasure is confined here; the safety story is
/// [`TreeArray::install_faulter`]'s contract (the faulter outlives its
/// installation window).
#[derive(Clone, Copy)]
struct FaulterPtr(*const (dyn LeafFaulter + 'static));

// SAFETY: the pointee is Sync (LeafFaulter: Sync) and the install
// contract keeps it alive for the installation window, so sending the
// pointer between threads adds nothing beyond what `&dyn LeafFaulter`
// already permits.
unsafe impl Send for FaulterPtr {}

impl<'a, T: Pod, A: BlockAlloc> TreeArray<'a, T, A> {
    /// Allocate a zeroed tree array of `len` elements using the paper's
    /// geometry (node size = allocator block size, 8-byte child ids).
    pub fn new(alloc: &'a A, len: usize) -> Result<Self> {
        let geo = TreeGeometry::new(alloc.block_size(), std::mem::size_of::<T>(), len)?;
        // Build bottom-up: leaves first, then interior levels. The
        // leaves-first order of `all` is a struct invariant (see the
        // `blocks` field docs).
        let nleaves = geo.nleaves();
        let mut all = Vec::with_capacity(geo.total_blocks());
        let mut level: Vec<BlockId> = alloc.alloc_many(nleaves)?;
        // The allocator only guarantees zero contents on a block's FIRST
        // use; recycled blocks carry stale data. The constructor promises
        // a zeroed array, so scrub the leaves explicitly.
        for leaf in &level {
            // SAFETY: leaf is live and exclusively ours.
            unsafe { std::ptr::write_bytes(alloc.block_ptr(*leaf), 0, alloc.block_size()) };
        }
        all.extend_from_slice(&level);
        let mut depth_built = 1;
        while level.len() > 1 || depth_built < geo.depth {
            let nparents = level.len().div_ceil(geo.fanout);
            let parents = match alloc.alloc_many(nparents) {
                Ok(p) => p,
                Err(e) => {
                    for b in &all {
                        let _ = alloc.free(*b);
                    }
                    return Err(e);
                }
            };
            // Record the parents *before* wiring children so a write
            // failure frees every block allocated so far (all-or-nothing,
            // like the alloc_many path above).
            all.extend_from_slice(&parents);
            for (pi, parent) in parents.iter().enumerate() {
                let lo = pi * geo.fanout;
                let hi = ((pi + 1) * geo.fanout).min(level.len());
                for (slot, child) in level[lo..hi].iter().enumerate() {
                    // Native-endian: child slots are later read/patched
                    // as `AtomicU64`s (see `child_at`).
                    let id64 = child.0 as u64;
                    if let Err(e) = alloc.write(*parent, slot * 8, &id64.to_ne_bytes()) {
                        for b in &all {
                            let _ = alloc.free(*b);
                        }
                        return Err(e);
                    }
                }
            }
            level = parents;
            depth_built += 1;
        }
        debug_assert_eq!(depth_built, geo.depth);
        Ok(TreeArray {
            alloc,
            geo,
            root: AtomicU32::new(level[0].0),
            blocks: all.iter().map(|b| AtomicU32::new(b.0)).collect(),
            generation: AtomicU64::new(0),
            flat_on: AtomicBool::new(false),
            flat: OnceLock::new(),
            seq: (0..geo.nleaves()).map(|_| AtomicU64::new(0)).collect(),
            swap_words: (0..geo.nleaves()).map(|_| AtomicU64::new(SWAP_RESIDENT)).collect(),
            touch: (0..geo.nleaves()).map(|_| AtomicU64::new(0)).collect(),
            touch_clock: AtomicU64::new(0),
            lock_waits_total: AtomicU64::new(0),
            seq_retries_total: AtomicU64::new(0),
            faulter: Mutex::new(None),
            _t: std::marker::PhantomData,
        })
    }

    /// Element count.
    #[inline]
    pub fn len(&self) -> usize {
        self.geo.len
    }

    /// True when the array holds no elements.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.geo.len == 0
    }

    /// Tree depth (1 = single leaf).
    #[inline]
    pub fn depth(&self) -> u32 {
        self.geo.depth
    }

    /// Geometry metadata.
    #[inline]
    pub fn geometry(&self) -> TreeGeometry {
        self.geo
    }

    /// Current root block.
    #[inline]
    fn root_block(&self) -> BlockId {
        BlockId(self.root.load(Ordering::Acquire))
    }

    /// Relocation generation. Translation caches snapshot this and
    /// revalidate when it moves (see the type-level docs).
    #[inline]
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::Acquire)
    }

    /// Read the 8-byte child pointer at `slot` of interior node `node`.
    ///
    /// Child slots are read and written as `AtomicU64`s (blocks are
    /// block-size-aligned and slots are 8-byte offsets, so the cast is
    /// aligned): relocation patches a slot with a `Release` store while
    /// concurrent readers walk with `Acquire` loads, making the walk
    /// data-race-free under [`TreeArray::migrate_leaf_concurrent`].
    #[inline]
    fn child_at(&self, node: BlockId, slot: usize) -> BlockId {
        // SAFETY: node is one of our live blocks; slot < fanout; the
        // slot address is 8-aligned per above.
        let id = unsafe {
            let p = self.alloc.block_ptr(node).add(slot * 8) as *const AtomicU64;
            (*p).load(Ordering::Acquire)
        };
        BlockId(id as u32)
    }

    /// Walk from the root to the leaf holding element `i`.
    /// This is the *naive* access of Table 2: `depth` dependent loads.
    #[inline]
    fn walk_to_leaf(&self, i: usize) -> BlockId {
        let mut node = self.root_block();
        for level in 0..self.geo.depth - 1 {
            node = self.child_at(node, self.geo.child_slot(level, i));
        }
        node
    }

    /// Switch on the flat leaf-table translation mode: one pointer per
    /// leaf, built lazily at the first translated access, collapsing
    /// `walk_to_leaf` to a single indexed load. Relocation keeps the
    /// table patched in O(1), so the mode stays valid across
    /// [`TreeArray::migrate_leaf`].
    pub fn enable_flat_table(&self) {
        self.flat_on.store(true, Ordering::Release);
    }

    /// Is the flat leaf-table mode on?
    pub fn flat_table_enabled(&self) -> bool {
        self.flat_on.load(Ordering::Relaxed)
    }

    /// Build the flat table: thanks to the leaves-first `blocks`
    /// invariant this is `nleaves` plain loads, no tree walks.
    fn build_flat_table(&self) -> Box<[AtomicPtr<u8>]> {
        (0..self.geo.nleaves())
            .map(|l| {
                let id = BlockId(self.blocks[l].load(Ordering::Acquire));
                // SAFETY: `id` is one of our live leaves.
                AtomicPtr::new(unsafe { self.alloc.block_ptr(id) })
            })
            .collect()
    }

    /// Base data pointer of leaf `leaf_idx` under the active translation
    /// mode: one indexed load (flat table) or a naive walk.
    #[inline]
    pub(crate) fn leaf_base_ptr(&self, leaf_idx: usize) -> *mut u8 {
        if self.flat_on.load(Ordering::Relaxed) {
            let tbl = self.flat.get_or_init(|| self.build_flat_table());
            tbl[leaf_idx].load(Ordering::Acquire)
        } else {
            let leaf = self.walk_to_leaf(leaf_idx * self.geo.leaf_cap);
            // SAFETY: leaf is live; pointer valid for the whole block.
            unsafe { self.alloc.block_ptr(leaf) }
        }
    }

    /// Pointer to element `i` (crate-internal; `i < len`).
    #[inline]
    pub(crate) fn elem_ptr(&self, i: usize) -> *mut T {
        let shift = self.geo.leaf_cap.trailing_zeros();
        let base = self.leaf_base_ptr(i >> shift) as *mut T;
        let p = unsafe { base.add(i & (self.geo.leaf_cap - 1)) };
        debug_assert_eq!(
            p as usize % std::mem::align_of::<T>(),
            0,
            "block alignment must imply element alignment (see Pod docs)"
        );
        p
    }

    /// Read element `i` (bounds-checked; naive tree walk unless the flat
    /// table is enabled).
    pub fn get(&self, i: usize) -> Result<T> {
        if i >= self.geo.len {
            return Err(Error::IndexOutOfBounds {
                index: i,
                len: self.geo.len,
            });
        }
        Ok(unsafe { self.get_unchecked(i) })
    }

    /// Read element `i` without bounds checking.
    ///
    /// # Safety
    /// `i < self.len()`.
    #[inline]
    pub unsafe fn get_unchecked(&self, i: usize) -> T {
        // Aligned read: see the Pod alignment contract.
        (self.elem_ptr(i) as *const T).read()
    }

    /// Write element `i` (bounds-checked).
    pub fn set(&mut self, i: usize, v: T) -> Result<()> {
        if i >= self.geo.len {
            return Err(Error::IndexOutOfBounds {
                index: i,
                len: self.geo.len,
            });
        }
        unsafe { self.set_unchecked(i, v) };
        Ok(())
    }

    /// Write element `i` without bounds checking.
    ///
    /// # Safety
    /// `i < self.len()`.
    #[inline]
    pub unsafe fn set_unchecked(&mut self, i: usize, v: T) {
        // Aligned write: see the Pod alignment contract.
        self.elem_ptr(i).write(v);
    }

    /// Raw leaf pointer + element span for leaf `leaf_idx`
    /// (crate-internal: powers [`Cursor`] and the leaf slices).
    #[inline]
    pub(crate) fn leaf_ptr(&self, leaf_idx: usize) -> (*mut T, usize) {
        let first_elem = leaf_idx * self.geo.leaf_cap;
        let span = self.geo.leaf_cap.min(self.geo.len - first_elem);
        (self.leaf_base_ptr(leaf_idx) as *mut T, span)
    }

    /// Borrow leaf `leaf_idx`'s elements as a slice (zero-copy: this is
    /// the exact 32 KB buffer the Pallas blocked kernel consumes).
    ///
    /// Relocation caveat: this slice borrows the tree, so the safe
    /// [`TreeArray::migrate_leaf`] (`&mut self`) cannot run while it is
    /// live — the borrow checker ties the slice to the leaf's
    /// *location*. The `unsafe` [`TreeArray::migrate_leaf_shared`]
    /// (`&self`) deliberately escapes that tie so cursors can coexist
    /// with moves; its safety contract forbids calling it while a slice
    /// of the moving leaf is held (the slice would keep pointing at the
    /// freed, possibly recycled block). Cursors and the batch APIs
    /// revalidate via the generation counter; raw slices cannot.
    pub fn leaf_slice(&self, leaf_idx: usize) -> &[T] {
        assert!(leaf_idx < self.geo.nleaves());
        let (p, span) = self.leaf_ptr(leaf_idx);
        // SAFETY: p valid for span elements; &self prevents writes
        // through the safe mutation API for the slice's lifetime, and
        // the caller upholds the no-relocation-while-borrowed contract
        // documented above.
        unsafe { std::slice::from_raw_parts(p, span) }
    }

    /// Mutably borrow leaf `leaf_idx`'s elements.
    pub fn leaf_slice_mut(&mut self, leaf_idx: usize) -> &mut [T] {
        assert!(leaf_idx < self.geo.nleaves());
        let (p, span) = self.leaf_ptr(leaf_idx);
        // SAFETY: as above, with exclusive borrow.
        unsafe { std::slice::from_raw_parts_mut(p, span) }
    }

    /// Number of leaf blocks.
    #[inline]
    pub fn nleaves(&self) -> usize {
        self.geo.nleaves()
    }

    /// Current physical block of leaf `leaf_idx` (one atomic load via
    /// the leaves-first `blocks` invariant). This is what background
    /// compaction ([`crate::mmd`]) inspects to decide whether a leaf is
    /// worth moving — no tree walk, no side effects.
    pub fn leaf_block(&self, leaf_idx: usize) -> BlockId {
        assert!(leaf_idx < self.geo.nleaves());
        BlockId(self.blocks[leaf_idx].load(Ordering::Acquire))
    }

    /// Current sequence word of leaf `leaf_idx`: odd = a write or a
    /// relocation is in flight; it advances by 2 per completed
    /// write/move. Custom readers can run the same
    /// begin/read/validate protocol [`TreeView`] uses; tests and
    /// benches use it to observe writer/relocation traffic.
    #[inline]
    pub fn leaf_seq(&self, leaf_idx: usize) -> u64 {
        self.seq[leaf_idx].load(Ordering::Acquire)
    }

    /// The raw sequence word of leaf `leaf_idx` (crate-internal: the
    /// read-side protocol in [`TreeView`] needs the atomic itself).
    #[inline]
    pub(crate) fn seq_word(&self, leaf_idx: usize) -> &AtomicU64 {
        &self.seq[leaf_idx]
    }

    /// Acquire leaf `leaf_idx`'s seqlock: spin until the word is even,
    /// then CAS it odd. Returns `(base, waits)` — the even value the
    /// lock was taken at (pass to [`TreeArray::seq_release`]) and how
    /// many attempts lost to contention (a writer/relocation holding or
    /// stealing the lock). The acquire is an `AcqRel` RMW, so data
    /// writes in the critical section cannot be reordered before the
    /// odd store, and the holder observes everything the previous
    /// holder published (in particular a relocation's generation bump —
    /// which is why translations validated *under* the lock are always
    /// current).
    pub(crate) fn seq_acquire(&self, leaf_idx: usize) -> (u64, u64) {
        let word = &self.seq[leaf_idx];
        let mut waits = 0u64;
        loop {
            let s = word.load(Ordering::Relaxed);
            if s & 1 == 0
                && word
                    .compare_exchange(s, s + 1, Ordering::AcqRel, Ordering::Relaxed)
                    .is_ok()
            {
                if waits > 0 {
                    // Contended acquisition: feed the tree-wide heat
                    // counter the mmd policy backs off on.
                    self.lock_waits_total.fetch_add(waits, Ordering::Relaxed);
                }
                return (s, waits);
            }
            waits += 1;
            if waits & 0x3F == 0 {
                // Long hold (a paused writer, a mid-copy relocation):
                // donate the timeslice instead of burning it.
                std::thread::yield_now();
            } else {
                std::hint::spin_loop();
            }
        }
    }

    /// Release leaf `leaf_idx`'s seqlock taken at `base`: publish every
    /// write of the critical section (Release) and land the word on the
    /// next even value, so readers straddling the section observe a
    /// changed sequence and retry.
    #[inline]
    pub(crate) fn seq_release(&self, leaf_idx: usize, base: u64) {
        debug_assert_eq!(self.seq[leaf_idx].load(Ordering::Relaxed), base + 1);
        self.seq[leaf_idx].store(base + 2, Ordering::Release);
    }

    /// [`TreeArray::seq_acquire`] wrapped in a drop guard: the lock is
    /// released even if the critical section unwinds (a panicking user
    /// closure, a failed debug assertion). Without this, an unwind
    /// would leave the word odd forever — every reader of the leaf
    /// would spin in its retry loop and every writer/relocation in
    /// `seq_acquire`, turning one failed assertion into a process-wide
    /// hang. Partial critical-section state released this way is still
    /// seq-consistent: each element store is complete, and the +2 makes
    /// straddling readers retry.
    #[inline]
    pub(crate) fn seq_lock(&self, leaf_idx: usize) -> (SeqLockGuard<'_, 'a, T, A>, u64) {
        let (base, waits) = self.seq_acquire(leaf_idx);
        (
            SeqLockGuard {
                tree: self,
                leaf_idx,
                base,
            },
            waits,
        )
    }

    /// Visit every leaf in order as one contiguous slice: `visit(leaf_idx,
    /// elems)`. One translation and one slice per leaf — the bulk-access
    /// primitive `to_vec`, `copy_from_slice`, and the workloads' checksum
    /// drains are built on, so whole-array traffic never pays a
    /// translation (or a bounds check) per element.
    ///
    /// The slice borrows the tree: the [`TreeArray::leaf_slice`]
    /// relocation caveat applies for the duration of each callback.
    pub fn for_each_leaf<F: FnMut(usize, &[T])>(&self, mut visit: F) {
        for leaf in 0..self.nleaves() {
            let (p, span) = self.leaf_ptr(leaf);
            // SAFETY: p valid for span elements under the &self borrow.
            visit(leaf, unsafe { std::slice::from_raw_parts(p as *const T, span) });
        }
    }

    /// Bulk-load from a slice: one translation + one memcpy per leaf.
    pub fn copy_from_slice(&mut self, src: &[T]) -> Result<()> {
        if src.len() != self.geo.len {
            return Err(Error::IndexOutOfBounds {
                index: src.len(),
                len: self.geo.len,
            });
        }
        let cap = self.geo.leaf_cap;
        for leaf in 0..self.nleaves() {
            let (p, span) = self.leaf_ptr(leaf);
            // SAFETY: p valid for span elements (&mut self: exclusive);
            // src covers [leaf*cap, leaf*cap+span) by the length check.
            unsafe { std::ptr::copy_nonoverlapping(src.as_ptr().add(leaf * cap), p, span) };
        }
        Ok(())
    }

    /// Copy out to a `Vec` (for verification against contiguous
    /// baselines): one translation + one memcpy per leaf via
    /// [`TreeArray::for_each_leaf`].
    pub fn to_vec(&self) -> Vec<T> {
        let mut out = Vec::with_capacity(self.geo.len);
        self.for_each_leaf(|_, elems| out.extend_from_slice(elems));
        out
    }

    // ---- Batched access (sort-and-run translation amortization) ----
    //
    // Random single-element access pays one translation per element; the
    // batched APIs group a whole batch of indices by leaf (stable
    // counting sort over leaf numbers — O(batch + nleaves)) and
    // translate each distinct leaf once per run. This is the software
    // counterpart of hardware TLB-reach batching, and what the batched
    // GUPS/hashprobe variants are built on.

    /// Bounds-check a batch of indices up front (all-or-nothing).
    pub(crate) fn check_batch(&self, idxs: &[usize]) -> Result<()> {
        for &i in idxs {
            if i >= self.geo.len {
                return Err(Error::IndexOutOfBounds {
                    index: i,
                    len: self.geo.len,
                });
            }
        }
        Ok(())
    }

    /// Positions of `idxs` stably grouped by leaf: counting sort when the
    /// leaf count is comparable to the batch, comparison sort otherwise.
    /// Stability preserves per-index program order, so read-modify-write
    /// batches keep per-slot semantics.
    pub(crate) fn leaf_order(&self, idxs: &[usize]) -> Vec<u32> {
        let shift = self.geo.leaf_cap.trailing_zeros();
        let nl = self.nleaves();
        let mut order = vec![0u32; idxs.len()];
        if nl <= idxs.len().saturating_mul(4).saturating_add(64) {
            let mut counts = vec![0u32; nl + 1];
            for &i in idxs {
                counts[(i >> shift) + 1] += 1;
            }
            for l in 1..=nl {
                counts[l] += counts[l - 1];
            }
            for (pos, &i) in idxs.iter().enumerate() {
                let l = i >> shift;
                order[counts[l] as usize] = pos as u32;
                counts[l] += 1;
            }
        } else {
            for (pos, slot) in order.iter_mut().enumerate() {
                *slot = pos as u32;
            }
            order.sort_by_key(|&p| idxs[p as usize] >> shift);
        }
        order
    }

    /// Read many elements; `out[k]` is element `idxs[k]`. One translation
    /// per *distinct leaf run*, not per element.
    pub fn get_batch(&self, idxs: &[usize]) -> Result<Vec<T>> {
        self.check_batch(idxs)?;
        let mut out = vec![T::default(); idxs.len()];
        let order = self.leaf_order(idxs);
        let shift = self.geo.leaf_cap.trailing_zeros();
        let mask = self.geo.leaf_cap - 1;
        let mut k = 0;
        while k < order.len() {
            let leaf = idxs[order[k] as usize] >> shift;
            let base = self.leaf_base_ptr(leaf) as *const T;
            while k < order.len() && idxs[order[k] as usize] >> shift == leaf {
                let pos = order[k] as usize;
                // SAFETY: bounds checked above; offset < leaf span.
                out[pos] = unsafe { base.add(idxs[pos] & mask).read() };
                k += 1;
            }
        }
        Ok(out)
    }

    /// Write many elements: element `idxs[k] = vals[k]`. Duplicate
    /// indices keep last-write-wins semantics (stable grouping).
    pub fn set_batch(&mut self, idxs: &[usize], vals: &[T]) -> Result<()> {
        if vals.len() != idxs.len() {
            return Err(Error::Config(format!(
                "set_batch: {} indices but {} values",
                idxs.len(),
                vals.len()
            )));
        }
        self.update_batch(idxs, |pos, slot| *slot = vals[pos])
    }

    /// Read-modify-write many elements: `f(k, &mut element(idxs[k]))` for
    /// every `k`, grouped by leaf. Calls for the *same index* (and, more
    /// broadly, the same leaf) happen in batch order; calls across
    /// different leaves are reordered — per-element updates must commute
    /// across distinct indices (GUPS xor, hash-probe accumulate do).
    pub fn update_batch<F: FnMut(usize, &mut T)>(&mut self, idxs: &[usize], mut f: F) -> Result<()> {
        self.check_batch(idxs)?;
        let order = self.leaf_order(idxs);
        let shift = self.geo.leaf_cap.trailing_zeros();
        let mask = self.geo.leaf_cap - 1;
        let mut k = 0;
        while k < order.len() {
            let leaf = idxs[order[k] as usize] >> shift;
            let base = self.leaf_base_ptr(leaf) as *mut T;
            while k < order.len() && idxs[order[k] as usize] >> shift == leaf {
                let pos = order[k] as usize;
                // SAFETY: bounds checked; &mut self gives exclusivity.
                f(pos, unsafe { &mut *base.add(idxs[pos] & mask) });
                k += 1;
            }
        }
        Ok(())
    }

    /// Visit `idxs` grouped into per-leaf runs: `visit(leaf_idx,
    /// leaf_elems, positions)` once per distinct leaf, where `positions`
    /// index into `idxs` (element `idxs[p]` is
    /// `leaf_elems[idxs[p] % leaf_cap]`). The traversal primitive the
    /// batch APIs are specializations of, public for workloads that want
    /// leaf-granular processing (e.g. handing whole leaves to a kernel).
    pub fn for_each_leaf_run<F>(&self, idxs: &[usize], mut visit: F) -> Result<()>
    where
        F: FnMut(usize, &[T], &[u32]),
    {
        self.check_batch(idxs)?;
        let order = self.leaf_order(idxs);
        let shift = self.geo.leaf_cap.trailing_zeros();
        let mut k = 0;
        while k < order.len() {
            let leaf = idxs[order[k] as usize] >> shift;
            let mut e = k + 1;
            while e < order.len() && idxs[order[e] as usize] >> shift == leaf {
                e += 1;
            }
            let (p, span) = self.leaf_ptr(leaf);
            // SAFETY: p valid for span elements under the &self borrow.
            let elems = unsafe { std::slice::from_raw_parts(p as *const T, span) };
            visit(leaf, elems, &order[k..e]);
            k = e;
        }
        Ok(())
    }

    /// Relocate one leaf to a fresh block, patching the single parent
    /// pointer (or the root). See `pmem::migrate` for the public API
    /// and the paper-§2 relocation story.
    ///
    /// Takes `&self`: the tree's location metadata is interior-mutable
    /// precisely so a leaf can move under live cursors — they revalidate
    /// through the generation bump (bumped *after* all pointers are
    /// patched, so a reader observing the new generation observes a
    /// consistent tree). Every pointer involved is patched atomically
    /// (parent slot `AtomicU64`, root/blocks/flat-table atomics), so a
    /// concurrent reader observes either the old or the new location,
    /// never a torn one. The arena epoch is bumped after the generation
    /// so caches over *other* trees in the pool revalidate too.
    ///
    /// Disposal of the displaced block:
    /// * `defer_free == false` — freed immediately. Requires that no
    ///   other thread accesses the tree during the move (the
    ///   [`TreeArray::migrate_leaf_shared`] contract): an in-flight
    ///   reader could otherwise still dereference the freed, possibly
    ///   recycled block.
    /// * `defer_free == true` — retired into the arena epoch's limbo
    ///   list; the pool recycles it only after every registered reader
    ///   has pinned the post-move epoch
    ///   ([`crate::pmem::ArenaEpoch::try_reclaim`]). This is what makes
    ///   [`TreeArray::migrate_leaf_concurrent`] safe under live
    ///   [`crate::trees::TreeView`] readers.
    ///
    /// Public callers reach this through the safe `&mut self`
    /// [`TreeArray::migrate_leaf`] or the `unsafe`
    /// [`TreeArray::migrate_leaf_shared`] /
    /// [`TreeArray::migrate_leaf_concurrent`].
    ///
    /// # Safety
    /// No live leaf slice of the tree across the call; concurrent access
    /// from other threads only as permitted by the chosen disposal mode
    /// above; at most one relocation of this tree in flight at a time.
    /// When `dest` is `Some`, it must be a live block exclusively owned
    /// by the caller (ownership transfers to the tree on success) and
    /// not referenced by any tree; `None` allocates from the pool —
    /// the destination-directed form is how compaction steers leaves
    /// into specific pool regions ([`crate::pmem::BlockAlloc::alloc_in_span`]).
    pub(crate) unsafe fn relocate_leaf_impl(
        &self,
        leaf_idx: usize,
        defer_free: bool,
        dest: Option<BlockId>,
    ) -> Result<BlockId> {
        // Allocate before locking: an OOM must not be held against a
        // leaf whose seqlock readers/writers are spinning on.
        let fresh = match dest {
            Some(d) => d,
            None => self.alloc.alloc()?,
        };
        // Take the leaf's seqlock for the copy + publication: a
        // concurrent TreeWriter can neither write the old block mid-copy
        // (the copy would tear, and post-publication writes to the old
        // block would be lost) nor translate to the old block after the
        // move (acquiring the lock next synchronizes with the release
        // below, so the generation bump is visible and the writer
        // re-translates). Readers straddling this section observe an
        // odd/changed sequence and retry. Guard form: released on drop
        // even if a debug assertion below unwinds.
        let (seq_guard, _) = self.seq_lock(leaf_idx);
        let (parent, old) = self.leaf_parent(leaf_idx);
        debug_assert_ne!(fresh.0, old.0, "destination must differ from the leaf's block");
        let bs = self.alloc.block_size();
        // SAFETY: both blocks live and distinct; full-block copy. A
        // concurrent reader may read `old` at the same time (read/read),
        // and `fresh` is unpublished until the pointer patches below.
        unsafe {
            std::ptr::copy_nonoverlapping(self.alloc.block_ptr(old), self.alloc.block_ptr(fresh), bs);
        }
        // SAFETY: fresh is live, exclusively ours, and now holds the
        // leaf's bytes; parent/old came from `leaf_parent` just above.
        let retire_epoch = unsafe { self.publish_leaf(leaf_idx, parent, fresh) };
        drop(seq_guard);
        if defer_free {
            // Concurrent readers may still hold the old translation:
            // park the block in limbo until they quiesce.
            self.alloc.epoch().retire(old, retire_epoch);
        } else {
            // The move is committed (pointers patched, counters bumped);
            // surfacing a free failure now would make a *completed*
            // migration look like a no-op. `old` is live by
            // construction, so free cannot fail for either shipped
            // allocator anyway.
            let freed = self.alloc.free(old);
            debug_assert!(freed.is_ok(), "freeing the displaced leaf failed: {freed:?}");
        }
        Ok(fresh)
    }

    /// Point leaf `leaf_idx` at `fresh` **without copying** from the
    /// currently recorded block — the restore half of leaf eviction
    /// ([`crate::mmd`]): the leaf's payload was already written into
    /// `fresh` by the caller (faulted from [`crate::pmem::SwapPool`]),
    /// and the previously recorded block is long dead. Patches the
    /// parent slot (or root), the leaves-first bookkeeping, and the
    /// flat table, then publishes via generation + epoch bumps exactly
    /// like a relocation.
    ///
    /// # Safety
    /// * `fresh` is live, exclusively owned by the caller (ownership
    ///   transfers to the tree), holds the leaf's bytes, and is not
    ///   referenced by any tree.
    /// * No accessor of this tree (cursor, view, slice, `get`/`set`)
    ///   may have run since the eviction that killed the old block, and
    ///   none may run concurrently with this call — between eviction
    ///   and adoption the leaf's recorded translation has no live
    ///   backing (the [`crate::trees::TreeRegistry`] evictable
    ///   contract).
    /// * At most one relocation/adoption of this tree in flight.
    pub(crate) unsafe fn adopt_leaf_impl(&self, leaf_idx: usize, fresh: BlockId) {
        debug_assert!(leaf_idx < self.geo.nleaves());
        // Belt-and-braces: adoption's contract already forbids every
        // accessor, but taking the (necessarily uncontended) seqlock
        // keeps the "a leaf's translation only changes under its
        // seqlock" invariant unconditional.
        let (_seq_guard, _) = self.seq_lock(leaf_idx);
        let (parent, _stale) = self.leaf_parent(leaf_idx);
        // SAFETY: forwarded from this fn's contract (no copy needed —
        // `fresh` already holds the bytes; the stale block is dead).
        unsafe { self.publish_leaf(leaf_idx, parent, fresh) };
    }

    // ---- Software page faults (evict / fault-in under the seqlock) ----
    //
    // The fault-capable eviction protocol. Eviction stashes a leaf's
    // payload in swap and records the slot in the leaf's *swap word* —
    // but leaves every translation pointer naming the retired block.
    // Accessors notice the swap word inside their seqlock bracket (the
    // evictor publishes it before releasing the seqlock, so a reader
    // whose begin-load observed the post-evict sequence value observes
    // the swap word too; a reader that raced reads committed pre-evict
    // bytes — the block sits in epoch limbo until it quiesces — or
    // fails its end check and retries). The faulting thread then takes
    // the leaf's seqlock and restores under it, so duplicate faults for
    // one leaf serialize on the seqlock and concurrent readers retry
    // rather than observe a half-restored leaf.

    /// Install `f` as this tree's fault handler: accessors that hit an
    /// evicted leaf call it to bring the payload back. Type- and
    /// lifetime-erased so the tree type does not grow parameters.
    ///
    /// # Safety
    /// `f` must outlive the installation window: every accessor fault
    /// and every [`TreeArray::clear_faulter`]/re-install must
    /// happen-before `f` is dropped. (In practice: install after
    /// creating the swap service, clear after accessor threads join.)
    pub unsafe fn install_faulter(&self, f: &dyn LeafFaulter) {
        // SAFETY: lifetime erasure only; the caller's contract keeps
        // the pointee alive while the pointer is reachable.
        let ptr = unsafe {
            std::mem::transmute::<*const (dyn LeafFaulter + '_), *const (dyn LeafFaulter + 'static)>(
                f as *const _,
            )
        };
        *self.faulter.lock().unwrap() = Some(FaulterPtr(ptr));
    }

    /// Remove the installed fault handler. Accessors hitting an evicted
    /// leaf afterwards get [`Error::SwappedOut`] instead of faulting.
    pub fn clear_faulter(&self) {
        *self.faulter.lock().unwrap() = None;
    }

    /// The installed fault handler, if any (fault path only — takes the
    /// cell's mutex).
    fn installed_faulter(&self) -> Option<&dyn LeafFaulter> {
        // SAFETY: install_faulter's contract keeps the pointee alive.
        self.faulter.lock().unwrap().map(|p| unsafe { &*p.0 })
    }

    /// Is leaf `leaf_idx` currently evicted? One relaxed load — the
    /// load-bearing check sits *inside* accessor seq brackets with
    /// Acquire; this form is for policy scans and tests.
    #[inline]
    pub fn leaf_swapped(&self, leaf_idx: usize) -> bool {
        self.swap_words[leaf_idx].load(Ordering::Relaxed) != SWAP_RESIDENT
    }

    /// The swap slot holding leaf `leaf_idx`'s payload, if evicted.
    pub fn leaf_swap_slot(&self, leaf_idx: usize) -> Option<SwapSlot> {
        let raw = self.swap_words[leaf_idx].load(Ordering::Acquire);
        (raw != SWAP_RESIDENT).then(|| SwapSlot::from_raw(raw))
    }

    /// Count of currently evicted leaves (a scan; policy-tick rate).
    pub fn swapped_leaves(&self) -> usize {
        (0..self.nleaves()).filter(|&l| self.leaf_swapped(l)).count()
    }

    /// The raw swap word of leaf `leaf_idx` (crate-internal: accessor
    /// brackets load it with Acquire between their sequence loads).
    #[inline]
    pub(crate) fn swap_word(&self, leaf_idx: usize) -> &AtomicU64 {
        &self.swap_words[leaf_idx]
    }

    /// Stamp leaf `leaf_idx` as just-touched (translation misses and
    /// fault-ins call this; per-element hits deliberately do not — the
    /// recency signal is coarse so the hot path stays two loads).
    #[inline]
    pub(crate) fn note_touch(&self, leaf_idx: usize) {
        let tick = self.touch_clock.fetch_add(1, Ordering::Relaxed) + 1;
        self.touch[leaf_idx].store(tick, Ordering::Relaxed);
    }

    /// Leaf `leaf_idx`'s last-touch tick (0 = never touched). Only
    /// comparable within this tree.
    #[inline]
    pub fn leaf_touch(&self, leaf_idx: usize) -> u64 {
        self.touch[leaf_idx].load(Ordering::Relaxed)
    }

    /// Total seqlock acquisition attempts lost to contention, summed
    /// over all leaves since construction (writer heat; the mmd policy
    /// watches the per-tick delta).
    pub fn lock_waits_total(&self) -> u64 {
        self.lock_waits_total.load(Ordering::Relaxed)
    }

    /// Total read-side seq-bracket retries over all views of this tree
    /// since construction (reader pain; the mmd policy watches the
    /// per-tick delta and defers compaction while it spikes).
    pub fn seq_retries_total(&self) -> u64 {
        self.seq_retries_total.load(Ordering::Relaxed)
    }

    /// Count one read-side seq-bracket retry (called by
    /// [`crate::trees::TreeView`] on every bracket re-run).
    #[inline]
    pub(crate) fn note_seq_retry(&self) {
        self.seq_retries_total.fetch_add(1, Ordering::Relaxed);
    }

    /// Evict leaf `leaf_idx` through `svc` under the leaf's seqlock:
    /// payload to swap, physical block into epoch limbo
    /// ([`SwapService::evict_deferred`]), slot recorded in the swap
    /// word. Translations keep naming the retired block on purpose —
    /// see the section comment. Fails with [`Error::SwappedOut`] if the
    /// leaf is already evicted.
    ///
    /// # Safety
    /// The tree must be operating under the fault-capable contract
    /// ([`crate::trees::TreeRegistry::register_evictable`]): every
    /// accessor runs a swap-checking path (seq-bracketed view/writer
    /// APIs), and a faulter is installed if any accessor may touch this
    /// leaf before it is restored.
    pub unsafe fn evict_leaf_via(&self, leaf_idx: usize, svc: &dyn SwapService) -> Result<SwapSlot> {
        assert!(leaf_idx < self.geo.nleaves());
        let (_guard, _) = self.seq_lock(leaf_idx);
        let block = BlockId(self.blocks[leaf_idx].load(Ordering::Acquire));
        if self.swap_words[leaf_idx].load(Ordering::Acquire) != SWAP_RESIDENT {
            return Err(Error::SwappedOut(block));
        }
        // The stash's read sees a stable leaf: we hold the seqlock, so
        // no writer can interleave bytes into the snapshot.
        let slot = svc.evict_deferred(block)?;
        // Publish the swap word *before* the guard's releasing store:
        // any accessor that observes the post-evict sequence value also
        // observes the slot.
        self.swap_words[leaf_idx].store(slot.raw(), Ordering::Release);
        Ok(slot)
    }

    /// Restore leaf `leaf_idx` through `faulter` under the leaf's
    /// seqlock: fault the payload into a fresh block, adopt it
    /// ([`TreeArray::publish_leaf`] — translation patch + generation
    /// bump), clear the swap word. Returns `false` if the leaf was
    /// already resident (an accessor's demand fault won the race).
    ///
    /// This is the daemon's restore/prefetch entry; accessor demand
    /// faults run the same routine via the installed faulter
    /// ([`TreeArray::fault_leaf_locked`] from inside their own held
    /// guard).
    pub(crate) fn restore_leaf_via(&self, leaf_idx: usize, faulter: &dyn LeafFaulter) -> Result<bool> {
        assert!(leaf_idx < self.geo.nleaves());
        let (_guard, _) = self.seq_lock(leaf_idx);
        // SAFETY: we hold the leaf's seqlock.
        unsafe { self.fault_leaf_locked(leaf_idx, faulter) }
    }

    /// Fault leaf `leaf_idx` back in with the *installed* faulter,
    /// taking (and releasing) the leaf's seqlock. The accessor fault
    /// hook for readers, which never hold the seqlock themselves.
    /// Returns `false` if the leaf turned out resident (a peer's fault
    /// or the daemon's restore won; the caller just retries its read).
    pub(crate) fn fault_leaf(&self, leaf_idx: usize) -> Result<bool> {
        let (_guard, _) = self.seq_lock(leaf_idx);
        // SAFETY: we hold the leaf's seqlock.
        unsafe { self.fault_leaf_under_guard(leaf_idx) }
    }

    /// The write-side accessor hook: [`TreeArray::fault_leaf`] for a
    /// caller *already holding* leaf `leaf_idx`'s seqlock (a
    /// [`crate::trees::TreeWriter`] inside its critical section —
    /// re-acquiring would self-deadlock).
    ///
    /// # Safety
    /// The caller holds leaf `leaf_idx`'s seqlock.
    pub(crate) unsafe fn fault_leaf_under_guard(&self, leaf_idx: usize) -> Result<bool> {
        let faulter = match self.installed_faulter() {
            Some(f) => f,
            None => {
                // Re-check under the lock: the leaf may have been
                // restored between the caller's check and our acquire.
                if self.swap_words[leaf_idx].load(Ordering::Acquire) == SWAP_RESIDENT {
                    return Ok(false);
                }
                return Err(Error::SwappedOut(BlockId(
                    self.blocks[leaf_idx].load(Ordering::Acquire),
                )));
            }
        };
        // SAFETY: forwarded caller contract.
        unsafe { self.fault_leaf_locked(leaf_idx, faulter) }
    }

    /// The locked core of every fault-in: re-check the swap word, read
    /// the payload back through `faulter`, adopt the fresh block, clear
    /// the swap word. Duplicate faults coalesce here — only the first
    /// claimant under the seqlock sees a non-resident swap word.
    ///
    /// # Safety
    /// The caller holds leaf `leaf_idx`'s seqlock.
    pub(crate) unsafe fn fault_leaf_locked(
        &self,
        leaf_idx: usize,
        faulter: &dyn LeafFaulter,
    ) -> Result<bool> {
        let raw = self.swap_words[leaf_idx].load(Ordering::Acquire);
        if raw == SWAP_RESIDENT {
            return Ok(false);
        }
        let fresh = faulter.fault_in(SwapSlot::from_raw(raw))?;
        let (parent, _stale) = self.leaf_parent(leaf_idx);
        // SAFETY: `fresh` is live, exclusively ours (fault_in transfers
        // ownership), and holds the leaf's bytes; `parent` is this
        // leaf's; the held seqlock serializes publication.
        unsafe { self.publish_leaf(leaf_idx, parent, fresh) };
        // Clear *after* the translation patch: an accessor observing
        // "resident" must also observe the fresh translation, which the
        // generation bump inside publish_leaf (and the guard's eventual
        // releasing store) guarantees for seq-bracketed readers.
        self.swap_words[leaf_idx].store(SWAP_RESIDENT, Ordering::Release);
        self.note_touch(leaf_idx);
        Ok(true)
    }

    /// Walk to leaf `leaf_idx`, recording the single parent slot that
    /// names it (`None` at depth 1: the leaf is the root). Returns the
    /// slot and the currently recorded leaf block.
    fn leaf_parent(&self, leaf_idx: usize) -> (Option<(BlockId, usize)>, BlockId) {
        let first_elem = leaf_idx * self.geo.leaf_cap;
        let mut node = self.root_block();
        let mut parent: Option<(BlockId, usize)> = None;
        for level in 0..self.geo.depth - 1 {
            let slot = self.geo.child_slot(level, first_elem);
            parent = Some((node, slot));
            node = self.child_at(node, slot);
        }
        debug_assert_eq!(
            self.blocks[leaf_idx].load(Ordering::Relaxed),
            node.0,
            "leaves-first blocks invariant violated"
        );
        (parent, node)
    }

    /// The *publication half* of every leaf move — the one copy of the
    /// load-bearing protocol shared by relocation
    /// ([`TreeArray::relocate_leaf_impl`]) and eviction restore
    /// ([`TreeArray::adopt_leaf_impl`]). Patches, in order: the parent
    /// slot (or root) atomically, the leaves-first `blocks`
    /// bookkeeping (one store — the invariant that keeps this O(1)),
    /// and the flat leaf table; then bumps the tree generation and
    /// finally the arena epoch. Same-tree caches revalidate on the
    /// generation, every cache in the arena on the epoch — bumped
    /// second, so observing the new epoch implies observing the new
    /// generation. Returns the post-publication epoch (the retire
    /// stamp for a displaced block).
    ///
    /// Flat-table patch uses `get_or_init` (not `get`) to close the
    /// build/patch race: if a reader is concurrently building the table
    /// from pre-patch `blocks` values, either its build wins and this
    /// store overwrites the stale entry, or this thread's build wins
    /// (already patched — `blocks[leaf_idx]` was stored above). Either
    /// way the table ends precise.
    ///
    /// # Safety
    /// `fresh` is live, exclusively the caller's (ownership transfers
    /// to the tree), and holds the leaf's bytes; `parent` came from
    /// [`TreeArray::leaf_parent`] for this leaf; at most one
    /// publication of this tree in flight.
    unsafe fn publish_leaf(
        &self,
        leaf_idx: usize,
        parent: Option<(BlockId, usize)>,
        fresh: BlockId,
    ) -> u64 {
        match parent {
            // SAFETY: p is a live interior block, slot < fanout, and the
            // slot address is 8-aligned (see `child_at`).
            Some((p, slot)) => unsafe {
                let sp = self.alloc.block_ptr(p).add(slot * 8) as *const AtomicU64;
                (*sp).store(fresh.0 as u64, Ordering::Release);
            },
            None => self.root.store(fresh.0, Ordering::Release), // depth-1: the leaf is the root
        }
        self.blocks[leaf_idx].store(fresh.0, Ordering::Release);
        if self.flat_on.load(Ordering::Relaxed) {
            let tbl = self.flat.get_or_init(|| self.build_flat_table());
            // SAFETY: fresh is live and ours.
            tbl[leaf_idx].store(unsafe { self.alloc.block_ptr(fresh) }, Ordering::Release);
        }
        self.generation.fetch_add(1, Ordering::Release);
        self.alloc.epoch().bump()
    }

    /// Sequential iterator using the Figure 2 cached-leaf optimization
    /// (plus the leaf-TLB for revisits).
    pub fn iter(&self) -> Cursor<'_, 'a, T, A> {
        Cursor::new(self)
    }

    /// A random-access cursor starting unpositioned, with the default
    /// leaf-TLB configuration ([`LeafTlb::DEFAULT_ENTRIES`] entries,
    /// [`LeafTlb::DEFAULT_WAYS`]-way).
    pub fn cursor(&self) -> Cursor<'_, 'a, T, A> {
        Cursor::new(self)
    }

    /// A cursor with an explicit TLB geometry. `entries == 0` disables
    /// the TLB, reproducing the bare single-leaf Figure 2 cursor.
    pub fn cursor_with_tlb(&self, entries: usize, ways: usize) -> Cursor<'_, 'a, T, A> {
        Cursor::with_tlb(self, LeafTlb::new(entries, ways))
    }

    /// A shared read view with its own leaf-TLB and epoch registration
    /// (default TLB geometry). Views are `Send` and independent: spawn
    /// one per worker thread for concurrent reads over one tree — no
    /// shared mutable TLB, no lock on the lookup path. See
    /// [`crate::trees::TreeView`].
    pub fn view(&self) -> TreeView<'_, 'a, T, A>
    where
        T: Sync,
    {
        TreeView::new(self, LeafTlb::default_for_cursor())
    }

    /// A shared read view with an explicit TLB geometry (`entries == 0`
    /// disables the TLB: every access re-translates, the re-walk
    /// baseline of the concurrency ablation).
    pub fn view_with_tlb(&self, entries: usize, ways: usize) -> TreeView<'_, 'a, T, A>
    where
        T: Sync,
    {
        TreeView::new(self, LeafTlb::new(entries, ways))
    }

    /// A concurrent write handle over this tree (default TLB geometry):
    /// writes take the target leaf's seqlock, so any number of writers
    /// coexist with [`TreeView`] readers and with
    /// [`TreeArray::migrate_leaf_concurrent`]-family relocation (the
    /// mmd compactor included). See [`TreeWriter`] for the protocol and
    /// the type-level "Writers" docs for the seqlock invariants.
    ///
    /// # Safety
    /// While any writer of this tree is live, the tree may be accessed
    /// only through seq-checked paths: **every** [`TreeView`] method
    /// (`get`/`get_batch`/`to_vec`/`for_each_leaf_run` — all
    /// seq-bracketed per leaf run), [`TreeWriter`] methods, and the
    /// concurrent relocation forms. Everything else must not overlap
    /// the writer's lifetime on any thread, because none of it retries
    /// on the sequence word and could observe a torn write: no
    /// [`TreeArray::leaf_slice`]-style raw slice, no [`Cursor`], no
    /// direct `get`/`set`/batch/`to_vec` calls on the `TreeArray`
    /// itself.
    pub unsafe fn writer(&self) -> TreeWriter<'_, 'a, T, A>
    where
        T: Sync,
    {
        TreeWriter::new(self, LeafTlb::default_for_cursor())
    }

    /// [`TreeArray::writer`] with an explicit TLB geometry
    /// (`entries == 0` disables the writer's translation cache).
    ///
    /// # Safety
    /// The [`TreeArray::writer`] contract.
    pub unsafe fn writer_with_tlb(&self, entries: usize, ways: usize) -> TreeWriter<'_, 'a, T, A>
    where
        T: Sync,
    {
        TreeWriter::new(self, LeafTlb::new(entries, ways))
    }
}

/// A held per-leaf seqlock (see [`TreeArray::seq_lock`]): releases on
/// drop, so unwinding out of a critical section cannot leave the leaf
/// permanently odd.
pub(crate) struct SeqLockGuard<'t, 'a, T: Pod, A: BlockAlloc> {
    tree: &'t TreeArray<'a, T, A>,
    leaf_idx: usize,
    base: u64,
}

impl<T: Pod, A: BlockAlloc> Drop for SeqLockGuard<'_, '_, T, A> {
    fn drop(&mut self) {
        self.tree.seq_release(self.leaf_idx, self.base);
    }
}

impl<T: Pod, A: BlockAlloc> Drop for TreeArray<'_, T, A> {
    fn drop(&mut self) {
        for b in self.blocks.iter() {
            let _ = self.alloc.free(BlockId(b.load(Ordering::Relaxed)));
        }
        // Teardown-time reclaim: blocks this tree's concurrent
        // migrations retired may still sit in the pool's limbo — give
        // them a non-blocking pass now that the tree is gone, so a
        // tree that was migrated under readers does not leak its
        // displaced blocks until someone else reclaims. Non-blocking on
        // purpose: a registered-but-idle reader elsewhere must not hang
        // an unrelated tree's drop (the daemon's shutdown path and
        // explicit `synchronize` handle the blocking case).
        self.alloc.epoch().try_reclaim(self.alloc);
    }
}

impl<T: Pod, A: BlockAlloc> std::fmt::Debug for TreeArray<'_, T, A> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "TreeArray {{ len: {}, depth: {}, leaves: {}, gen: {} }}",
            self.geo.len,
            self.geo.depth,
            self.nleaves(),
            self.generation()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{forall, Rng};

    fn small_alloc() -> BlockAllocator {
        // 1 KB blocks keep trees deep at tiny sizes: leaf_cap(f32)=256,
        // fanout=128.
        BlockAllocator::new(1024, 4096).unwrap()
    }

    #[test]
    fn depth1_roundtrip() {
        let a = small_alloc();
        let mut t: TreeArray<f32> = TreeArray::new(&a, 100).unwrap();
        assert_eq!(t.depth(), 1);
        for i in 0..100 {
            t.set(i, i as f32).unwrap();
        }
        for i in 0..100 {
            assert_eq!(t.get(i).unwrap(), i as f32);
        }
    }

    #[test]
    fn depth2_roundtrip() {
        let a = small_alloc();
        let n = 256 * 60; // 60 leaves -> depth 2
        let mut t: TreeArray<f32> = TreeArray::new(&a, n).unwrap();
        assert_eq!(t.depth(), 2);
        for i in (0..n).step_by(7) {
            t.set(i, (i * 3) as f32).unwrap();
        }
        for i in (0..n).step_by(7) {
            assert_eq!(t.get(i).unwrap(), (i * 3) as f32);
        }
    }

    #[test]
    fn depth3_roundtrip() {
        let a = BlockAllocator::new(1024, 1 << 16).unwrap();
        let n = 256 * 128 * 3 + 17; // > fanout leaves -> depth 3
        let mut t: TreeArray<u32> = TreeArray::new(&a, n).unwrap();
        assert_eq!(t.depth(), 3);
        let idxs = [0usize, 1, 255, 256, 32767, 32768, n - 1];
        for &i in &idxs {
            t.set(i, i as u32 ^ 0xDEAD).unwrap();
        }
        for &i in &idxs {
            assert_eq!(t.get(i).unwrap(), i as u32 ^ 0xDEAD);
        }
    }

    #[test]
    fn oob_get_set_rejected() {
        let a = small_alloc();
        let mut t: TreeArray<u8> = TreeArray::new(&a, 10).unwrap();
        assert!(t.get(10).is_err());
        assert!(t.set(10, 0).is_err());
    }

    #[test]
    fn zero_initialized() {
        let a = small_alloc();
        let t: TreeArray<u64> = TreeArray::new(&a, 1000).unwrap();
        assert!(t.iter().all(|v| v == 0));
    }

    #[test]
    fn zero_initialized_even_on_recycled_blocks() {
        // Blocks freed by a dropped tree carry stale data; a new tree
        // over the same pool must still read all-zero.
        let a = small_alloc();
        {
            let mut t: TreeArray<u64> = TreeArray::new(&a, 1000).unwrap();
            for i in 0..1000 {
                t.set(i, 0xDEAD_BEEF).unwrap();
            }
        }
        let t2: TreeArray<u64> = TreeArray::new(&a, 1000).unwrap();
        assert!(t2.iter().all(|v| v == 0), "recycled leaves not scrubbed");
    }

    #[test]
    fn blocks_freed_on_drop() {
        let a = small_alloc();
        {
            let _t: TreeArray<f32> = TreeArray::new(&a, 256 * 60).unwrap();
            assert!(a.stats().allocated > 60); // leaves + root
        }
        assert_eq!(a.stats().allocated, 0);
    }

    #[test]
    fn alloc_failure_leaks_nothing() {
        let a = BlockAllocator::new(1024, 32).unwrap();
        // 60 leaves needed but only 32 blocks available.
        assert!(TreeArray::<f32>::new(&a, 256 * 60).is_err());
        assert_eq!(a.stats().allocated, 0);
    }

    #[test]
    fn copy_from_slice_to_vec_roundtrip() {
        let a = small_alloc();
        let n = 256 * 10 + 13;
        let mut t: TreeArray<f32> = TreeArray::new(&a, n).unwrap();
        let src: Vec<f32> = (0..n).map(|i| i as f32 * 0.5).collect();
        t.copy_from_slice(&src).unwrap();
        assert_eq!(t.to_vec(), src);
    }

    #[test]
    fn leaf_slice_matches_elements() {
        let a = small_alloc();
        let n = 256 * 3 + 40; // 4 leaves, last partial
        let mut t: TreeArray<u32> = TreeArray::new(&a, n).unwrap();
        for i in 0..n {
            t.set(i, i as u32).unwrap();
        }
        assert_eq!(t.leaf_slice(0).len(), 256);
        assert_eq!(t.leaf_slice(3).len(), 40);
        assert_eq!(t.leaf_slice(1)[5], 256 + 5);
    }

    #[test]
    fn prop_tree_matches_vec_model() {
        forall(30, |g| {
            let a = BlockAllocator::new(1024, 1 << 14).unwrap();
            let n = g.usize_in(1, 256 * 200);
            let mut t: TreeArray<u32> = TreeArray::new(&a, n).unwrap();
            let mut model = vec![0u32; n];
            for _ in 0..g.usize_in(1, 300) {
                let i = g.usize_in(0, n - 1);
                let v = g.rng().next_u32();
                t.set(i, v).unwrap();
                model[i] = v;
            }
            // Spot-check random reads + full to_vec.
            for _ in 0..50 {
                let i = g.usize_in(0, n - 1);
                assert_eq!(t.get(i).unwrap(), model[i]);
            }
            assert_eq!(t.to_vec(), model);
        });
    }

    #[test]
    fn prop_paper_block_size_geometry() {
        // With real 32 KB blocks: 4 KB fits depth 1, 4 MB depth 2.
        let a = BlockAllocator::new(32 * 1024, 512).unwrap();
        let t1: TreeArray<f32> = TreeArray::new(&a, 1024).unwrap(); // 4 KB
        assert_eq!(t1.depth(), 1);
        let t2: TreeArray<f32> = TreeArray::new(&a, 1 << 20).unwrap(); // 4 MB
        assert_eq!(t2.depth(), 2);
    }

    #[test]
    fn large_u8_tree() {
        let a = small_alloc();
        let n = 1024 * 130; // u8: leaf_cap 1024, fanout 128 -> depth 3
        let mut t: TreeArray<u8> = TreeArray::new(&a, n).unwrap();
        assert_eq!(t.depth(), 3);
        let mut rng = Rng::new(5);
        let mut pairs = Vec::new();
        for _ in 0..200 {
            let i = rng.range(0, n);
            let v = rng.next_u32() as u8;
            t.set(i, v).unwrap();
            pairs.push((i, v));
        }
        // last write wins per index
        let mut expect = std::collections::HashMap::new();
        for (i, v) in pairs {
            expect.insert(i, v);
        }
        for (i, v) in expect {
            assert_eq!(t.get(i).unwrap(), v);
        }
    }

    // ---- translation-cache / flat-table / batch tests ----

    #[test]
    fn flat_table_matches_walks() {
        let a = BlockAllocator::new(1024, 1 << 14).unwrap();
        let n = 256 * 70 + 9; // depth 2, partial last leaf
        let mut t: TreeArray<u32> = TreeArray::new(&a, n).unwrap();
        let data: Vec<u32> = (0..n as u32).map(|i| i.wrapping_mul(2246822519)).collect();
        t.copy_from_slice(&data).unwrap();
        assert!(!t.flat_table_enabled());
        t.enable_flat_table();
        assert!(t.flat_table_enabled());
        let mut rng = Rng::new(3);
        for _ in 0..500 {
            let i = rng.range(0, n);
            assert_eq!(t.get(i).unwrap(), data[i]);
        }
        assert_eq!(t.to_vec(), data);
    }

    #[test]
    fn flat_table_survives_relocation() {
        let a = BlockAllocator::new(1024, 1 << 12).unwrap();
        let n = 256 * 6 + 3;
        let mut t: TreeArray<u32> = TreeArray::new(&a, n).unwrap();
        let data: Vec<u32> = (0..n as u32).collect();
        t.copy_from_slice(&data).unwrap();
        t.enable_flat_table();
        assert_eq!(t.get(300).unwrap(), 300); // builds the table
        let g0 = t.generation();
        for leaf in 0..t.nleaves() {
            t.migrate_leaf(leaf).unwrap();
        }
        assert_eq!(t.generation(), g0 + t.nleaves() as u64);
        assert_eq!(t.to_vec(), data, "flat table stale after relocation");
        // Writes through the flat path land in the fresh blocks too.
        t.set(300, 77).unwrap();
        assert_eq!(t.get(300).unwrap(), 77);
    }

    #[test]
    fn relocate_bumps_generation_and_keeps_bookkeeping() {
        let a = BlockAllocator::new(1024, 256).unwrap();
        let mut t: TreeArray<u32> = TreeArray::new(&a, 256 * 3).unwrap();
        let data: Vec<u32> = (0..256 * 3).map(|i| i as u32 ^ 0xBEEF).collect();
        t.copy_from_slice(&data).unwrap();
        let live = a.stats().allocated;
        assert_eq!(t.generation(), 0);
        let fresh = t.migrate_leaf(1).unwrap();
        assert_eq!(t.generation(), 1);
        assert!(a.is_live(fresh));
        assert_eq!(a.stats().allocated, live, "relocation must not leak");
        assert_eq!(t.to_vec(), data);
        // Dropping the tree must free the *fresh* block (bookkeeping
        // patched, not stale).
        drop(t);
        assert_eq!(a.stats().allocated, 0);
    }

    #[test]
    fn get_batch_matches_pointwise() {
        let a = BlockAllocator::new(1024, 1 << 14).unwrap();
        let n = 256 * 33 + 100;
        let mut t: TreeArray<u32> = TreeArray::new(&a, n).unwrap();
        let data: Vec<u32> = (0..n as u32).map(|i| i.wrapping_mul(2654435761)).collect();
        t.copy_from_slice(&data).unwrap();
        let mut rng = Rng::new(11);
        let idxs: Vec<usize> = (0..3000).map(|_| rng.range(0, n)).collect();
        let got = t.get_batch(&idxs).unwrap();
        for (k, &i) in idxs.iter().enumerate() {
            assert_eq!(got[k], data[i], "batch[{k}] (elem {i})");
        }
    }

    #[test]
    fn set_batch_last_write_wins() {
        let a = small_alloc();
        let n = 256 * 4;
        let mut t: TreeArray<u32> = TreeArray::new(&a, n).unwrap();
        // Duplicate index 700: the later value must stick.
        let idxs = [700usize, 3, 700, 1000, 700];
        let vals = [1u32, 2, 3, 4, 5];
        t.set_batch(&idxs, &vals).unwrap();
        assert_eq!(t.get(700).unwrap(), 5);
        assert_eq!(t.get(3).unwrap(), 2);
        assert_eq!(t.get(1000).unwrap(), 4);
    }

    #[test]
    fn update_batch_equals_per_op_loop() {
        let a = BlockAllocator::new(1024, 1 << 14).unwrap();
        let n = 256 * 20;
        let mut t: TreeArray<u64> = TreeArray::new(&a, n).unwrap();
        let mut model = vec![0u64; n];
        let mut rng = Rng::new(77);
        let pairs: Vec<(usize, u64)> =
            (0..5000).map(|_| (rng.range(0, n), rng.next_u64())).collect();
        for &(i, k) in &pairs {
            model[i] ^= k; // per-op reference
        }
        let idxs: Vec<usize> = pairs.iter().map(|p| p.0).collect();
        t.update_batch(&idxs, |pos, v| *v ^= pairs[pos].1).unwrap();
        assert_eq!(t.to_vec(), model);
    }

    #[test]
    fn batch_oob_rejected_before_any_write() {
        let a = small_alloc();
        let mut t: TreeArray<u32> = TreeArray::new(&a, 100).unwrap();
        assert!(t.get_batch(&[5, 100]).is_err());
        assert!(t.set_batch(&[5, 100], &[1, 2]).is_err());
        assert!(t.set_batch(&[5], &[1, 2]).is_err(), "length mismatch");
        assert_eq!(t.get(5).unwrap(), 0, "failed batch must not write");
    }

    #[test]
    fn for_each_leaf_run_groups_by_leaf() {
        let a = small_alloc();
        let n = 256 * 5;
        let mut t: TreeArray<u32> = TreeArray::new(&a, n).unwrap();
        let data: Vec<u32> = (0..n as u32).collect();
        t.copy_from_slice(&data).unwrap();
        // Indices hitting leaves 4, 0, 4, 1 -> runs for leaves {0, 1, 4}.
        let idxs = [1100usize, 5, 1150, 300];
        let mut seen = Vec::new();
        t.for_each_leaf_run(&idxs, |leaf, elems, positions| {
            for &p in positions {
                let off = idxs[p as usize] % 256;
                assert_eq!(elems[off], data[idxs[p as usize]]);
            }
            seen.push((leaf, positions.len()));
        })
        .unwrap();
        seen.sort_unstable();
        assert_eq!(seen, vec![(0, 1), (1, 1), (4, 2)]);
    }

    #[test]
    fn prop_get_batch_matches_model_all_allocators() {
        use crate::pmem::ShardedAllocator;
        forall(15, |g| {
            let n = g.usize_in(1, 256 * 60);
            let nb = g.usize_in(0, 400);
            let a = BlockAllocator::new(1024, 1 << 12).unwrap();
            let s = ShardedAllocator::with_shards(1024, 1 << 12, 4).unwrap();
            let data: Vec<u32> = (0..n).map(|_| g.rng().next_u32()).collect();
            let idxs: Vec<usize> = (0..nb).map(|_| g.usize_in(0, n - 1)).collect();
            let mut t1: TreeArray<u32> = TreeArray::new(&a, n).unwrap();
            let mut t2: TreeArray<u32, ShardedAllocator> = TreeArray::new(&s, n).unwrap();
            t1.copy_from_slice(&data).unwrap();
            t2.copy_from_slice(&data).unwrap();
            t2.enable_flat_table();
            let want: Vec<u32> = idxs.iter().map(|&i| data[i]).collect();
            assert_eq!(t1.get_batch(&idxs).unwrap(), want);
            assert_eq!(t2.get_batch(&idxs).unwrap(), want);
        });
    }

    // A multi-field #[repr(C)] Pod exercising the alignment contract:
    // size 8 (power of two), align 4 — element offsets are multiples of
    // 8, so the aligned read/write path is sound.
    #[repr(C)]
    #[derive(Clone, Copy, Default, PartialEq, Debug)]
    struct Pair {
        lo: u32,
        hi: u32,
    }
    unsafe impl Pod for Pair {}

    #[test]
    fn repr_c_pod_roundtrips_aligned() {
        assert!(std::mem::size_of::<Pair>().is_power_of_two());
        assert_eq!(std::mem::size_of::<Pair>() % std::mem::align_of::<Pair>(), 0);
        let a = small_alloc();
        let n = 128 * 6 + 10; // 1 KB blocks, 8-byte elems: leaf_cap 128
        let mut t: TreeArray<Pair> = TreeArray::new(&a, n).unwrap();
        assert_eq!(t.geometry().leaf_cap, 128);
        for i in 0..n {
            t.set(i, Pair { lo: i as u32, hi: !(i as u32) }).unwrap();
        }
        for i in 0..n {
            assert_eq!(t.get(i).unwrap(), Pair { lo: i as u32, hi: !(i as u32) });
        }
        // Cursor and batch paths share the alignment story.
        let collected: Vec<Pair> = t.iter().collect();
        assert_eq!(collected[200], Pair { lo: 200, hi: !200u32 });
        let got = t.get_batch(&[0, 500, 129]).unwrap();
        assert_eq!(got[1], Pair { lo: 500, hi: !500u32 });
    }

    // ---- software page-fault primitives ----

    #[test]
    fn evict_leaf_and_restore_roundtrip() {
        use crate::pmem::SwapPool;
        let a = BlockAllocator::new(1024, 64).unwrap();
        let swap = SwapPool::anonymous(&a).unwrap();
        let n = 256 * 4;
        let mut t: TreeArray<u32> = TreeArray::new(&a, n).unwrap();
        let data: Vec<u32> = (0..n as u32).collect();
        t.copy_from_slice(&data).unwrap();
        assert!(!t.leaf_swapped(2));
        let slot = unsafe { t.evict_leaf_via(2, &swap) }.unwrap();
        assert!(t.leaf_swapped(2));
        assert_eq!(t.leaf_swap_slot(2), Some(slot));
        assert_eq!(t.swapped_leaves(), 1);
        assert!(
            unsafe { t.evict_leaf_via(2, &swap) }.is_err(),
            "double eviction must be rejected"
        );
        // Translation still names the retired block (in limbo) — the
        // swap word is what keeps accessors off it.
        assert!(a.is_live(t.leaf_block(2)));
        assert!(t.restore_leaf_via(2, &swap).unwrap());
        assert!(!t.leaf_swapped(2));
        assert_eq!(t.to_vec(), data, "payload must survive the roundtrip");
        assert!(!t.restore_leaf_via(2, &swap).unwrap(), "second restore is a no-op");
    }

    #[test]
    fn fault_without_faulter_is_a_typed_error() {
        use crate::pmem::SwapPool;
        let a = BlockAllocator::new(1024, 64).unwrap();
        let swap = SwapPool::anonymous(&a).unwrap();
        let mut t: TreeArray<u32> = TreeArray::new(&a, 256 * 2).unwrap();
        let data: Vec<u32> = (0..512u32).collect();
        t.copy_from_slice(&data).unwrap();
        unsafe { t.evict_leaf_via(1, &swap) }.unwrap();
        assert!(
            matches!(t.fault_leaf(1), Err(Error::SwappedOut(_))),
            "no faulter installed: the hook must surface a typed error, not panic"
        );
        // SAFETY: `swap` outlives every fault below and the clear.
        unsafe { t.install_faulter(&swap) };
        assert!(t.fault_leaf(1).unwrap());
        assert!(!t.fault_leaf(1).unwrap(), "resident leaf: hook must no-op");
        t.clear_faulter();
        assert_eq!(t.to_vec(), data);
    }

    #[test]
    fn touch_ticks_order_by_recency() {
        let a = small_alloc();
        let t: TreeArray<u32> = TreeArray::new(&a, 256 * 3).unwrap();
        assert_eq!(t.leaf_touch(0), 0, "untouched leaves read 0");
        t.note_touch(2);
        t.note_touch(0);
        t.note_touch(2);
        assert!(t.leaf_touch(2) > t.leaf_touch(0), "later touches must rank hotter");
        assert!(t.leaf_touch(0) > t.leaf_touch(1));
        assert_eq!(t.lock_waits_total(), 0, "uncontended trees report no waits");
    }
}
