//! Concurrent write-side translation: per-leaf seqlock writers.
//!
//! PR 3 made the read side concurrent — N [`TreeView`] readers over one
//! tree, no lock on the lookup path — but left mutation behind
//! `&mut TreeArray`, which the borrow checker rules out while any view
//! is alive. This module closes the gap: a [`TreeWriter`] is a `Send`
//! write handle that coexists with live views *and* with the
//! relocation traffic the mmd daemon generates, by serializing on the
//! finest lock the structure affords — one sequence word per leaf.
//!
//! # The protocol
//!
//! Each leaf of a [`TreeArray`] carries an atomic sequence word
//! (odd = write or relocation in flight, +2 per completed mutation):
//!
//! * **Writers** acquire the target leaf's seqlock (CAS even → odd),
//!   re-validate their translation *under the lock*, write, and release
//!   (store even). Writers of different leaves never touch the same
//!   word; same-leaf writers serialize on the CAS.
//! * **Readers** ([`TreeView::get`] / [`TreeView::get_batch`]) bracket
//!   each leaf read with two sequence loads and retry on an odd or
//!   changed value — a torn or mid-write value is never returned.
//! * **Relocation** (`migrate_leaf*`, and therefore the
//!   [`crate::mmd`] compactor) acquires the seqlock before copying, so
//!   a leaf is never simultaneously written and moved: the copy cannot
//!   tear a write, and no write can land on the displaced block after
//!   its bytes were copied out.
//!
//! # Why translations validated under the lock are always current
//!
//! Relocation publishes the new location (pointer patches + generation
//! bump) *inside* the leaf's locked section. A writer's acquire-CAS
//! synchronizes with the previous holder's release, so after acquiring,
//! the writer's generation read observes any completed move of this
//! leaf; a generation mismatch invalidates the writer's TLB entry and
//! forces a re-walk through the patched pointers. And while the writer
//! holds the lock, no relocation of that leaf can begin — the block it
//! translated to stays the leaf's current block for the whole critical
//! section. This is what makes the write path safe *without* epoch
//! limbo: the writer never dereferences a retired translation.
//!
//! The writer still **pins the arena epoch like a reader**
//! ([`crate::pmem::ReaderSlot`]): its read paths ([`TreeWriter::get`],
//! the read half of [`TreeWriter::update`]) and its cached translations
//! are governed by the same QSBR contract as views, and pinning also
//! keeps reclamation honest about a writer idling between bursts.
//!
//! # Evicted leaves (software page faults)
//!
//! On an evictable tree a target leaf may be in swap. Every writer
//! path checks the leaf's swap word *after* acquiring its seqlock and,
//! on a hit, faults the payload back in right there — via
//! [`TreeArray::fault_leaf_under_guard`], reusing the already-held
//! guard (re-acquiring would self-deadlock). The eviction protocol
//! publishes the swap word before releasing the leaf's seqlock, so a
//! writer that acquires after an eviction always sees it; a writer
//! that acquired first blocks the eviction instead. No faulter
//! installed surfaces [`crate::error::Error::SwappedOut`]; a dead
//! backing surfaces [`crate::error::Error::SwapFaultFailed`].
//!
//! # What stays on the caller
//!
//! Creating a writer is `unsafe` ([`TreeArray::writer`]): for the
//! writer's whole lifetime, every access to the tree — on any thread —
//! must go through seq-checked paths (every [`TreeView`] method —
//! including the bulk paths, which snapshot under the bracket — writer
//! methods, concurrent relocation). Raw leaf slices, cursors, and the
//! plain `TreeArray::get`/`set`/batch/`to_vec` calls do not retry on
//! the sequence word and could observe a torn write.
//!
//! Formal caveat, inherited by every seqlock ever shipped: a reader's
//! speculative load of a leaf mid-write is a data race in the abstract
//! memory model. The implementation follows the standard mitigation
//! (volatile element accesses on the racing paths, acquire/release
//! fences on the sequence word, racy values discarded by the retry
//! loop) — the same pragmatics the kernel's seqlocks and crossbeam's
//! `SeqLock` rely on.

use std::sync::atomic::Ordering;

use crate::error::{Error, Result};
use crate::pmem::epoch::ReaderSlot;
use crate::pmem::{BlockAlloc, BlockAllocator};
use crate::trees::tlb::{LeafTlb, TlbStats};
use crate::trees::tree_array::{Pod, SeqLockGuard, TreeArray, SWAP_RESIDENT};
#[allow(unused_imports)] // rustdoc links
use crate::trees::view::TreeView;

/// A `Send` concurrent write handle over a [`TreeArray`], with a
/// private leaf-TLB and an arena-epoch registration. Create one per
/// writer thread via the `unsafe` [`TreeArray::writer`]; see the module
/// docs for the seqlock protocol and the safety contract.
pub struct TreeWriter<'t, 'a, T: Pod, A: BlockAlloc = BlockAllocator> {
    tree: &'t TreeArray<'a, T, A>,
    /// This writer's private translation cache — never shared, never
    /// locked; entries are only dereferenced after re-validation under
    /// the target leaf's seqlock.
    tlb: LeafTlb,
    /// Tree generation TLB entries are stamped against.
    gen: u64,
    /// Arena epoch last observed; the TLB flushes when it moves.
    epoch_seen: u64,
    /// Registration with the arena epoch (pinned on every access).
    slot: ReaderSlot<'a>,
    /// Full translations performed (TLB misses that walked/indexed).
    walks: u64,
    /// Elements written through this writer.
    writes: u64,
    /// Seqlock acquisition attempts that lost to contention (another
    /// writer or a relocation holding the same leaf).
    lock_waits: u64,
    /// Software page faults this writer triggered: accesses that found
    /// their leaf evicted and brought it back in.
    faults: u64,
}

// SAFETY: same argument as TreeView's — the raw pointers inside the
// LeafTlb point into the allocator's arena (outlives 'a), and are
// dereferenced only on the owning thread after re-validation under the
// target leaf's seqlock (writes) or the epoch-pin + seq-check protocol
// (reads). The remaining fields are a `&TreeArray` (Sync for T: Sync),
// a thread-safe ReaderSlot, and counters.
unsafe impl<T: Pod + Sync, A: BlockAlloc> Send for TreeWriter<'_, '_, T, A> {}

impl<'t, 'a, T: Pod + Sync, A: BlockAlloc> TreeWriter<'t, 'a, T, A> {
    pub(crate) fn new(tree: &'t TreeArray<'a, T, A>, tlb: LeafTlb) -> Self {
        let slot = tree.alloc.epoch().register();
        let epoch_seen = slot.pin();
        TreeWriter {
            tree,
            tlb,
            gen: tree.generation(),
            epoch_seen,
            slot,
            walks: 0,
            writes: 0,
            lock_waits: 0,
            faults: 0,
        }
    }

    /// Element count of the underlying tree.
    #[inline]
    pub fn len(&self) -> usize {
        self.tree.len()
    }

    /// True when the underlying tree holds no elements.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.tree.is_empty()
    }

    /// Leaf blocks of the underlying tree.
    #[inline]
    pub fn nleaves(&self) -> usize {
        self.tree.nleaves()
    }

    /// Pin the arena epoch and run the shootdown checks — identical to
    /// the [`TreeView`] read-side pin (the writer is a registered epoch
    /// reader too; see the module docs).
    ///
    /// LOCKSTEP: this is a deliberate twin of `TreeView::pin` in
    /// `view.rs` — the flush-on-epoch-move + generation-restamp
    /// protocol must change in both places or neither (a fix applied
    /// to one copy leaves the other unsound).
    #[inline]
    fn pin(&mut self) {
        let e = self.slot.pin();
        if e != self.epoch_seen {
            self.epoch_seen = e;
            self.tlb.flush();
        }
        self.gen = self.tree.generation();
    }

    /// Translate `leaf_idx` **while holding its seqlock**: refresh the
    /// generation first (the acquire-CAS synchronized with any
    /// completed relocation's release, so the value read here covers
    /// every move of this leaf — see the module docs), then serve from
    /// the TLB or walk. The returned base pointer is the leaf's current
    /// block for as long as the lock is held.
    #[inline]
    fn locked_base(&mut self, leaf_idx: usize) -> *mut T {
        let g = self.tree.generation();
        if g != self.gen {
            self.gen = g;
        }
        if let Some((p, _)) = self.tlb.lookup(leaf_idx, self.gen) {
            return p as *mut T;
        }
        let (p, span) = self.tree.leaf_ptr(leaf_idx);
        self.walks += 1;
        self.tlb.insert(leaf_idx, self.gen, p as *mut u8, span);
        p
    }

    /// Acquire leaf `leaf_idx`'s seqlock, folding contention into this
    /// writer's counters. The guard releases on drop — including an
    /// unwind out of a panicking user closure, which must not leave
    /// the leaf's word odd (readers would spin forever).
    #[inline]
    fn lock_leaf(&mut self, leaf_idx: usize) -> SeqLockGuard<'t, 'a, T, A> {
        let (guard, waits) = self.tree.seq_lock(leaf_idx);
        self.lock_waits += waits;
        guard
    }

    /// Software-page-fault hook for the write paths: with `leaf`'s
    /// seqlock held (witnessed by `_guard`), fault the leaf in if it is
    /// evicted. On `Ok` the leaf is resident and the next
    /// [`TreeWriter::locked_base`] translates to the restored block
    /// (the fault bumped the generation, so stale TLB entries miss).
    /// Call *before* `locked_base` — the fault republishes the
    /// translation.
    #[inline]
    fn fault_locked(&mut self, leaf: usize, _guard: &SeqLockGuard<'t, 'a, T, A>) -> Result<()> {
        if self.tree.swap_word(leaf).load(Ordering::Acquire) == SWAP_RESIDENT {
            return Ok(());
        }
        self.faults += 1;
        // SAFETY: `_guard` is this leaf's held seqlock.
        unsafe { self.tree.fault_leaf_under_guard(leaf)? };
        Ok(())
    }

    /// Write element `i` (bounds-checked). On an evictable tree this
    /// may fault the leaf in; fault failures surface as
    /// [`Error::SwappedOut`] (no faulter installed) or
    /// [`Error::SwapFaultFailed`] (backing store gave up).
    pub fn set(&mut self, i: usize, v: T) -> Result<()> {
        if i >= self.len() {
            return Err(Error::IndexOutOfBounds {
                index: i,
                len: self.len(),
            });
        }
        // SAFETY: bounds checked.
        unsafe { self.try_set_unchecked(i, v) }
    }

    /// Write element `i` without bounds checking.
    ///
    /// Convenience wrapper over [`TreeWriter::try_set_unchecked`].
    ///
    /// # Panics
    /// When the leaf is evicted and cannot be faulted back in — use the
    /// `try_` form where swap failures must be handled.
    ///
    /// # Safety
    /// `i < self.len()`.
    #[inline]
    pub unsafe fn set_unchecked(&mut self, i: usize, v: T) {
        // SAFETY: forwarded caller contract.
        unsafe { self.try_set_unchecked(i, v) }
            .expect("swap fault-in failed in TreeWriter::set_unchecked")
    }

    /// Write element `i` without bounds checking; an evicted leaf is
    /// faulted back in under the already-held seqlock (module docs).
    ///
    /// # Safety
    /// `i < self.len()`.
    #[inline]
    pub unsafe fn try_set_unchecked(&mut self, i: usize, v: T) -> Result<()> {
        self.pin();
        let shift = self.tree.geo.leaf_cap.trailing_zeros();
        let leaf = i >> shift;
        let guard = self.lock_leaf(leaf);
        self.fault_locked(leaf, &guard)?;
        let p = self.locked_base(leaf);
        // SAFETY: in-bounds per caller; current block per locked_base;
        // volatile so racing seq-checked readers retry on a torn value
        // instead of the compiler assuming exclusivity (module docs).
        unsafe { p.add(i & (self.tree.geo.leaf_cap - 1)).write_volatile(v) };
        self.writes += 1;
        drop(guard);
        Ok(())
    }

    /// Read-modify-write element `i` under its leaf's seqlock: `f` sees
    /// the current value and its result is published atomically with
    /// respect to seq-checked readers and other writers of the leaf.
    pub fn update(&mut self, i: usize, f: impl FnOnce(T) -> T) -> Result<T> {
        if i >= self.len() {
            return Err(Error::IndexOutOfBounds {
                index: i,
                len: self.len(),
            });
        }
        self.pin();
        let shift = self.tree.geo.leaf_cap.trailing_zeros();
        let leaf = i >> shift;
        // Guard, not a bare release: `f` is user code — if it panics,
        // the unwind must still release the seqlock.
        let guard = self.lock_leaf(leaf);
        self.fault_locked(leaf, &guard)?;
        let p = self.locked_base(leaf);
        // SAFETY: in-bounds (checked); exclusive under the seqlock.
        let p = unsafe { p.add(i & (self.tree.geo.leaf_cap - 1)) };
        let old = unsafe { p.read() };
        let new = f(old);
        // SAFETY: as in set_unchecked.
        unsafe { p.write_volatile(new) };
        self.writes += 1;
        drop(guard);
        Ok(new)
    }

    /// Read element `i` (bounds-checked). The writer reads under the
    /// leaf's seqlock — briefly excluding same-leaf writers — which
    /// keeps the value exact without the view-style retry loop.
    pub fn get(&mut self, i: usize) -> Result<T> {
        if i >= self.len() {
            return Err(Error::IndexOutOfBounds {
                index: i,
                len: self.len(),
            });
        }
        self.pin();
        let shift = self.tree.geo.leaf_cap.trailing_zeros();
        let leaf = i >> shift;
        let guard = self.lock_leaf(leaf);
        self.fault_locked(leaf, &guard)?;
        let p = self.locked_base(leaf);
        // SAFETY: in-bounds (checked); exclusive under the seqlock.
        let v = unsafe { p.add(i & (self.tree.geo.leaf_cap - 1)).read() };
        drop(guard);
        Ok(v)
    }

    /// Write many elements (element `idxs[k] = vals[k]`), grouped by
    /// leaf so each distinct leaf run costs one seqlock acquisition and
    /// one TLB probe. Duplicate indices keep last-write-wins semantics
    /// (the grouping is stable).
    pub fn set_batch(&mut self, idxs: &[usize], vals: &[T]) -> Result<()> {
        if vals.len() != idxs.len() {
            return Err(Error::Config(format!(
                "set_batch: {} indices but {} values",
                idxs.len(),
                vals.len()
            )));
        }
        self.update_batch(idxs, |pos, slot| *slot = vals[pos])
    }

    /// Read-modify-write many elements: `f(k, &mut element(idxs[k]))`
    /// for every `k`, grouped by leaf; each leaf run executes atomically
    /// with respect to seq-checked readers and other writers of that
    /// leaf (one seqlock hold per run). Same commutativity contract as
    /// [`TreeArray::update_batch`]: calls for the same leaf happen in
    /// batch order, calls across leaves are reordered. On a fault-in
    /// failure mid-batch the error is returned with earlier leaf runs
    /// already applied (each run commits atomically; the batch as a
    /// whole is not transactional — it never was across leaves).
    pub fn update_batch<F: FnMut(usize, &mut T)>(&mut self, idxs: &[usize], mut f: F) -> Result<()> {
        self.tree.check_batch(idxs)?;
        self.pin();
        let order = self.tree.leaf_order(idxs);
        let shift = self.tree.geo.leaf_cap.trailing_zeros();
        let mask = self.tree.geo.leaf_cap - 1;
        let mut k = 0;
        while k < order.len() {
            let leaf = idxs[order[k] as usize] >> shift;
            let mut e = k + 1;
            while e < order.len() && idxs[order[e] as usize] >> shift == leaf {
                e += 1;
            }
            // Guard, not a bare release: `f` is user code — if it
            // panics, the unwind must still release the seqlock (the
            // partially applied run is seq-consistent: every committed
            // element store is whole, and straddling readers retry).
            let guard = self.lock_leaf(leaf);
            self.fault_locked(leaf, &guard)?;
            let p = self.locked_base(leaf);
            for &pos in &order[k..e] {
                let pos = pos as usize;
                // SAFETY: bounds checked above; exclusive under the
                // seqlock. The RMW is staged through a local so the
                // closure never holds `&mut` into memory a concurrent
                // reader is read_volatile-ing, and the commit is one
                // volatile store — same mitigation as the scalar paths
                // (module docs).
                let ep = unsafe { p.add(idxs[pos] & mask) };
                let mut v = unsafe { ep.read() };
                f(pos, &mut v);
                unsafe { ep.write_volatile(v) };
            }
            self.writes += (e - k) as u64;
            drop(guard);
            k = e;
        }
        // Batched pinning: one pin covered the whole batch where
        // per-access pinning (the scalar set/update paths) would have
        // paid idxs.len(). `set_batch` delegates here, so it is
        // covered too.
        self.slot.record_saved_pins(idxs.len().saturating_sub(1) as u64);
        Ok(())
    }

    /// Go offline: reclamation stops waiting on this writer until its
    /// next access. Call when a worker idles between write bursts.
    pub fn park(&self) {
        self.slot.unpin();
    }

    /// This writer's private TLB counters.
    pub fn tlb_stats(&self) -> TlbStats {
        self.tlb.stats()
    }

    /// Full translations (TLB misses) this writer performed.
    pub fn walks(&self) -> u64 {
        self.walks
    }

    /// Elements written through this writer.
    pub fn writes(&self) -> u64 {
        self.writes
    }

    /// Seqlock acquisition attempts that lost to contention.
    pub fn lock_waits(&self) -> u64 {
        self.lock_waits
    }

    /// Software page faults this writer triggered (accesses that found
    /// their leaf evicted). 0 on fully-resident workloads.
    pub fn faults(&self) -> u64 {
        self.faults
    }
}

/// Cloning spawns a *fresh* writer over the same tree: same TLB
/// geometry, empty cache, zeroed counters, its own epoch registration —
/// the way one writer fans out across scoped worker threads. The
/// original [`TreeArray::writer`] safety contract covers every clone.
impl<T: Pod + Sync, A: BlockAlloc> Clone for TreeWriter<'_, '_, T, A> {
    fn clone(&self) -> Self {
        TreeWriter::new(self.tree, LeafTlb::new(self.tlb.capacity(), self.tlb.ways()))
    }
}

impl<T: Pod, A: BlockAlloc> std::fmt::Debug for TreeWriter<'_, '_, T, A> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "TreeWriter {{ len: {}, gen: {}, epoch: {}, writes: {}, lock_waits: {}, tlb: {:?} }}",
            self.tree.len(),
            self.gen,
            self.epoch_seen,
            self.writes,
            self.lock_waits,
            self.tlb.stats()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::{BlockAllocator, ShardedAllocator};
    use crate::testutil::Rng;

    fn filled<A: BlockAlloc>(a: &A, n: usize) -> (TreeArray<'_, u64, A>, Vec<u64>) {
        let mut t: TreeArray<u64, A> = TreeArray::new(a, n).unwrap();
        let data: Vec<u64> = (0..n as u64).map(|i| i.wrapping_mul(0x9E37_79B9_7F4A_7C15)).collect();
        t.copy_from_slice(&data).unwrap();
        (t, data)
    }

    #[test]
    fn writer_set_get_roundtrip_and_bounds() {
        let a = BlockAllocator::new(1024, 64).unwrap();
        let (t, data) = filled(&a, 128 * 3 + 5);
        // SAFETY: all access below goes through writer/view methods.
        let mut w = unsafe { t.writer() };
        assert_eq!(w.get(7).unwrap(), data[7]);
        w.set(7, 42).unwrap();
        assert_eq!(w.get(7).unwrap(), 42);
        assert_eq!(w.writes(), 1);
        assert!(w.set(w.len(), 0).is_err());
        assert!(w.get(w.len()).is_err());
        assert!(w.update(w.len(), |v| v).is_err());
    }

    #[test]
    fn writer_bumps_the_leaf_seq_by_two_per_write() {
        let a = BlockAllocator::new(1024, 64).unwrap();
        let (t, _) = filled(&a, 128 * 2);
        let mut w = unsafe { t.writer() };
        assert_eq!(t.leaf_seq(0), 0);
        w.set(3, 1).unwrap();
        assert_eq!(t.leaf_seq(0), 2, "one write = one seqlock cycle");
        assert_eq!(t.leaf_seq(1), 0, "other leaves untouched");
        w.update(3, |v| v + 1).unwrap();
        assert_eq!(t.leaf_seq(0), 4);
        assert_eq!(w.get(3).unwrap(), 2);
    }

    #[test]
    fn views_observe_writer_stores() {
        let a = ShardedAllocator::with_shards(1024, 64, 2).unwrap();
        let (t, data) = filled(&a, 128 * 4);
        let mut v = t.view();
        assert_eq!(v.get(200).unwrap(), data[200]); // cache leaf 1
        let mut w = unsafe { t.writer() };
        w.set(200, 0xFEED).unwrap();
        assert_eq!(v.get(200).unwrap(), 0xFEED, "view must see the committed write");
        let got = v.get_batch(&[0, 200, 300]).unwrap();
        assert_eq!(got[1], 0xFEED);
    }

    #[test]
    fn writer_survives_concurrent_relocation_of_its_cached_leaf() {
        // Single-threaded shape of the writer/migrator handoff: the
        // writer caches leaf 0's translation, the leaf migrates
        // (deferred free), and the next write must re-translate to the
        // fresh block — not write the retired one.
        let a = BlockAllocator::new(1024, 64).unwrap();
        let (t, data) = filled(&a, 128 * 3);
        let mut w = unsafe { t.writer() };
        w.set(1, 111).unwrap(); // caches leaf 0
        let seq0 = t.leaf_seq(0);
        // SAFETY: accessors are the epoch-registered writer only.
        unsafe { t.migrate_leaf_concurrent(0) }.unwrap();
        assert_eq!(t.leaf_seq(0), seq0 + 2, "relocation must cycle the seqlock");
        w.set(2, 222).unwrap();
        assert_eq!(w.get(1).unwrap(), 111, "pre-move write must survive the copy");
        assert_eq!(w.get(2).unwrap(), 222, "post-move write must land in the fresh block");
        assert_eq!(w.get(130).unwrap(), data[130]);
        drop(w);
        a.epoch().synchronize(&a);
    }

    #[test]
    fn panicking_user_closure_releases_the_seqlock() {
        // A panic unwinding out of an update closure must not leave the
        // leaf's sequence word odd — that would wedge every reader,
        // writer, and relocation of the leaf forever.
        let a = BlockAllocator::new(1024, 64).unwrap();
        let (t, data) = filled(&a, 128 * 2);
        let mut w = unsafe { t.writer() };
        let boom = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = w.update(3, |_| panic!("user closure"));
        }));
        assert!(boom.is_err());
        assert_eq!(t.leaf_seq(0) % 2, 0, "panic left the seqlock held");
        // The leaf still serves reads, writes, and relocation.
        let mut v = t.view();
        assert_eq!(v.get(3).unwrap(), data[3]);
        w.set(3, 9).unwrap();
        assert_eq!(v.get(3).unwrap(), 9);
        // SAFETY: accessors are the registered view + writer only.
        unsafe { t.migrate_leaf_concurrent(0) }.unwrap();
        assert_eq!(v.get(3).unwrap(), 9);
        drop(w);
        drop(v);
        a.epoch().synchronize(&a);
    }

    #[test]
    fn update_batch_matches_scalar_updates() {
        let a = BlockAllocator::new(1024, 1 << 10).unwrap();
        let n = 128 * 12;
        let (t, data) = filled(&a, n);
        let mut model = data.clone();
        let mut rng = Rng::new(99);
        let pairs: Vec<(usize, u64)> =
            (0..4000).map(|_| (rng.range(0, n), rng.next_u64())).collect();
        for &(i, k) in &pairs {
            model[i] ^= k;
        }
        let idxs: Vec<usize> = pairs.iter().map(|p| p.0).collect();
        {
            let mut w = unsafe { t.writer() };
            w.update_batch(&idxs, |pos, v| *v ^= pairs[pos].1).unwrap();
            assert_eq!(w.writes(), idxs.len() as u64);
            assert!(w.set_batch(&[0], &[1, 2]).is_err(), "length mismatch");
            assert!(w.update_batch(&[n], |_, _| {}).is_err(), "oob batch");
        }
        assert_eq!(t.to_vec(), model);
    }

    #[test]
    fn batch_paths_amortize_epoch_pins() {
        // Satellite of the two-level PR: get_batch / update_batch /
        // for_each_leaf_run pin the arena epoch once per batch; the
        // pins they did NOT take (vs per-access pinning) surface in
        // EpochStats::saved_pins.
        let a = BlockAllocator::new(1024, 64).unwrap();
        let n = 128 * 4;
        let (t, _) = filled(&a, n);
        let idxs: Vec<usize> = (0..n).step_by(3).collect();
        {
            let mut w = unsafe { t.writer() };
            w.update_batch(&idxs, |_, v| *v = !*v).unwrap();
        }
        let after_write = a.epoch().stats();
        assert!(
            after_write.saved_pins >= idxs.len() as u64 - 1,
            "update_batch must credit batch-amortized pins: {after_write:?}"
        );
        let mut v = t.view();
        let _ = v.get_batch(&idxs).unwrap();
        let s = a.epoch().stats();
        assert!(
            s.saved_pins >= after_write.saved_pins + idxs.len() as u64 - 1,
            "get_batch must credit batch-amortized pins: {s:?}"
        );
        assert!(s.pins >= 2, "real pins still counted: {s:?}");
        assert!(
            s.pins < s.saved_pins,
            "batching should save more pins than it spends here: {s:?}"
        );
    }

    #[test]
    fn writer_faults_evicted_leaves_back_in() {
        use crate::pmem::SwapPool;
        let a = BlockAllocator::new(1024, 64).unwrap();
        let (t, data) = filled(&a, 128 * 3);
        let swap = SwapPool::anonymous(&a).unwrap();
        // SAFETY: `swap` outlives the faulter (cleared below).
        unsafe { t.install_faulter(&swap) };
        // SAFETY: all access below goes through writer/view methods.
        let mut w = unsafe { t.writer() };
        // SAFETY: accessors are fault-capable (faulter installed).
        unsafe { t.evict_leaf_via(1, &swap) }.unwrap();
        assert!(t.leaf_swapped(1));
        w.set(130, 7).unwrap();
        assert_eq!(w.faults(), 1, "set must fault the leaf in");
        assert!(!t.leaf_swapped(1));
        assert_eq!(w.get(131).unwrap(), data[131], "neighbors survived the roundtrip");
        unsafe { t.evict_leaf_via(1, &swap) }.unwrap();
        assert_eq!(w.update(130, |v| v + 1).unwrap(), 8, "update must fault + RMW");
        unsafe { t.evict_leaf_via(0, &swap) }.unwrap();
        w.update_batch(&[0, 130], |_, v| *v = !*v).unwrap();
        assert_eq!(w.faults(), 3, "update and update_batch each faulted once");
        t.clear_faulter();
        drop(w);
        assert_eq!(t.get(131).unwrap(), data[131]);
    }

    #[test]
    fn writer_fault_without_faulter_is_a_typed_error() {
        use crate::pmem::SwapPool;
        let a = BlockAllocator::new(1024, 64).unwrap();
        let (t, data) = filled(&a, 128 * 2);
        let swap = SwapPool::anonymous(&a).unwrap();
        let mut w = unsafe { t.writer() };
        // SAFETY: this test's accessors check the swap word and handle
        // the error; nothing dereferences the evicted leaf.
        unsafe { t.evict_leaf_via(1, &swap) }.unwrap();
        assert!(matches!(w.set(128, 1), Err(Error::SwappedOut(_))));
        assert!(matches!(w.get(128), Err(Error::SwappedOut(_))));
        assert!(matches!(w.update_batch(&[128], |_, _| {}), Err(Error::SwappedOut(_))));
        assert_eq!(w.get(0).unwrap(), data[0], "resident leaves unaffected");
        // The daemon's restore path still works without a faulter.
        assert!(t.restore_leaf_via(1, &swap).unwrap());
        w.set(128, 1).unwrap();
        assert_eq!(w.get(128).unwrap(), 1);
    }

    #[test]
    fn scoped_writer_threads_on_disjoint_and_shared_leaves() {
        // 4 writers hammer one tree with commuting updates; the final
        // contents must equal the per-thread streams applied to a
        // mirror in any order.
        let a = ShardedAllocator::with_shards(1024, 1 << 10, 4).unwrap();
        let n = 128 * 16;
        let (t, data) = filled(&a, n);
        let mut model = data.clone();
        let streams: Vec<Vec<(usize, u64)>> = (0..4u64)
            .map(|tid| {
                let mut rng = Rng::new(0xBEEF + tid);
                (0..3000).map(|_| (rng.range(0, n), rng.next_u64())).collect()
            })
            .collect();
        for s in &streams {
            for &(i, k) in s {
                model[i] = model[i].wrapping_add(k);
            }
        }
        let t = &t;
        let streams = &streams;
        std::thread::scope(|s| {
            for st in streams.iter() {
                s.spawn(move || {
                    // SAFETY: all concurrent access is via writers.
                    let mut w = unsafe { t.writer() };
                    for &(i, k) in st {
                        w.update(i, |v| v.wrapping_add(k)).unwrap();
                    }
                });
            }
        });
        assert_eq!(t.to_vec(), model, "concurrent commuting writes lost or tore an update");
    }
}
