//! The live-tree registry the memory-management daemon walks.
//!
//! Background compaction ([`crate::mmd`]) has to relocate leaves of
//! trees it did not create and whose element types it cannot name, so
//! the registry holds **type-erased** handles: [`CompactTarget`]
//! exposes exactly the entry points the daemon needs — where a leaf
//! lives ([`CompactTarget::leaf_block`]), move it to a chosen
//! destination ([`CompactTarget::relocate_leaf_to`], the epoch-deferred
//! [`TreeArray::migrate_leaf_concurrent_to`] underneath), park it in
//! swap ([`CompactTarget::evict_leaf`]) and bring it back
//! ([`CompactTarget::restore_leaf`]), plus the telemetry a policy wants
//! (swap residency, per-leaf access recency, writer contention).
//!
//! # Registration contracts (why `register*` is `unsafe`)
//!
//! Registering hands the daemon a standing licence to run
//! `migrate_leaf_concurrent`-family operations on the tree at any
//! moment, so the *caller* must uphold that function's contract for the
//! whole registration window:
//!
//! * **[`TreeRegistry::register`]** (compaction + rebalancing): the
//!   tree is accessed only through epoch-registered revalidating
//!   accessors — [`crate::trees::TreeView`] readers and
//!   [`crate::trees::TreeWriter`] seqlock writers (the daemon's
//!   relocation takes each leaf's seqlock, so writes and moves of one
//!   leaf serialize); no raw leaf slices, no cursors on other threads,
//!   no writes outside `TreeWriter`, and nobody else migrates its
//!   leaves.
//! * **[`TreeRegistry::register_evictable`]** (adds pressure-driven
//!   leaf eviction): additionally, every accessor must be
//!   **fault-capable** — a `TreeView` or `TreeWriter`, whose access
//!   paths check the per-leaf swap word inside their seq
//!   brackets/critical sections and fault an evicted leaf back in —
//!   and a [`crate::pmem::LeafFaulter`] must be installed on the tree
//!   ([`TreeArray::install_faulter`]) before any such access can hit an
//!   evicted leaf. (Before the fault hooks existed this contract was
//!   "no accessors at all"; live readers and writers over an evictable
//!   tree are now the *point* of the subsystem.) Raw paths — leaf
//!   slices, cursors, plain `TreeArray` calls — remain forbidden: they
//!   check nothing and would read a retired block's stale bytes.
//!
//! Deregistration synchronizes with the daemon: [`TreeRegistry`] holds
//! one mutex over the entry list and compaction passes run under it, so
//! once [`TreeRegistry::deregister`] returns the daemon can no longer
//! touch the tree and it may be dropped or mutated freely.
//! Deregistering (or dropping) a tree **with swapped-out leaves** is a
//! bug — the tree's bookkeeping still names a limbo-retired block whose
//! payload lives in swap, and dropping would double-free it — so
//! `deregister` panics in that state; the daemon's shutdown path
//! restores every evicted leaf first, which is the intended order.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};

use crate::error::Result;
use crate::pmem::faultq::{LeafFaulter, SwapService};
use crate::pmem::{BlockAlloc, BlockId, SwapSlot};
use crate::trees::tree_array::{Pod, TreeArray};

/// Type-erased handle to a live tree whose leaves the daemon may
/// relocate and evict. Implemented by [`TreeArray`] for `Sync` element
/// types; implementable by any block-backed structure whose nodes are
/// named by exactly one parent pointer (the paper's relocation
/// property).
pub trait CompactTarget: Sync {
    /// Leaf blocks in the structure.
    fn nleaves(&self) -> usize;

    /// Current physical block of leaf `leaf`.
    fn leaf_block(&self, leaf: usize) -> BlockId;

    /// Leaves currently parked in swap.
    fn swapped_leaves(&self) -> usize;

    /// The swap slot holding leaf `leaf`'s payload, if evicted.
    fn leaf_swap_slot(&self, leaf: usize) -> Option<SwapSlot>;

    /// Leaf `leaf`'s last-touch tick (0 = never; larger = hotter).
    /// Only comparable within one structure.
    fn leaf_touch(&self, leaf: usize) -> u64;

    /// Total seqlock acquisitions lost to contention over the
    /// structure's lifetime (writer heat; policies watch the delta).
    fn lock_waits(&self) -> u64;

    /// Total read-side seq-bracket retries over the structure's
    /// lifetime (reader pain; policies watch the delta and defer
    /// compaction while it spikes). Structures without revalidating
    /// readers report 0.
    fn seq_retries(&self) -> u64 {
        0
    }

    /// Move leaf `leaf` into `dest`, retiring the displaced block into
    /// the pool's epoch limbo. On error the caller keeps `dest`.
    ///
    /// # Safety
    /// The [`TreeArray::migrate_leaf_concurrent_to`] contract: readers
    /// only through epoch-registered views, no raw slices, single
    /// migrator, and `dest` live + exclusively owned by the caller.
    unsafe fn relocate_leaf_to(&self, leaf: usize, dest: BlockId) -> Result<()>;

    /// Park leaf `leaf` in swap through `svc` (payload stashed, block
    /// epoch-retired, swap word published under the leaf's seqlock).
    ///
    /// # Safety
    /// The [`TreeRegistry::register_evictable`] contract: every
    /// accessor is fault-capable, and a faulter is installed if any of
    /// them may touch this leaf before it is restored.
    unsafe fn evict_leaf(&self, leaf: usize, svc: &dyn SwapService) -> Result<SwapSlot>;

    /// Bring leaf `leaf` back from swap through `faulter` (the daemon's
    /// restore/prefetch entry — accessor demand faults use the tree's
    /// installed faulter instead). Returns `false` if the leaf was
    /// already resident: a demand fault won the race, which is fine.
    fn restore_leaf(&self, leaf: usize, faulter: &dyn LeafFaulter) -> Result<bool>;
}

impl<T: Pod + Sync, A: BlockAlloc> CompactTarget for TreeArray<'_, T, A> {
    fn nleaves(&self) -> usize {
        TreeArray::nleaves(self)
    }

    fn leaf_block(&self, leaf: usize) -> BlockId {
        TreeArray::leaf_block(self, leaf)
    }

    fn swapped_leaves(&self) -> usize {
        TreeArray::swapped_leaves(self)
    }

    fn leaf_swap_slot(&self, leaf: usize) -> Option<SwapSlot> {
        TreeArray::leaf_swap_slot(self, leaf)
    }

    fn leaf_touch(&self, leaf: usize) -> u64 {
        TreeArray::leaf_touch(self, leaf)
    }

    fn lock_waits(&self) -> u64 {
        TreeArray::lock_waits_total(self)
    }

    fn seq_retries(&self) -> u64 {
        TreeArray::seq_retries_total(self)
    }

    unsafe fn relocate_leaf_to(&self, leaf: usize, dest: BlockId) -> Result<()> {
        // SAFETY: forwarded verbatim.
        unsafe { self.migrate_leaf_concurrent_to(leaf, dest) }.map(|_| ())
    }

    unsafe fn evict_leaf(&self, leaf: usize, svc: &dyn SwapService) -> Result<SwapSlot> {
        // SAFETY: forwarded verbatim.
        unsafe { self.evict_leaf_via(leaf, svc) }
    }

    fn restore_leaf(&self, leaf: usize, faulter: &dyn LeafFaulter) -> Result<bool> {
        self.restore_leaf_via(leaf, faulter)
    }
}

/// One registered tree: the erased handle and the eviction permission.
/// (Swap residency lives in the tree itself — the per-leaf swap words —
/// not here: accessors fault leaves back in without going anywhere near
/// the registry lock.)
pub(crate) struct RegEntry<'e> {
    pub(crate) id: u64,
    pub(crate) tree: &'e (dyn CompactTarget + 'e),
    pub(crate) evictable: bool,
    /// Owning tenant ([`crate::pmem::tenant`]); `DEFAULT_TENANT` (0)
    /// for single-tenant registrations. The daemon's tenant-aware
    /// passes route each entry's swap traffic through its tenant's
    /// backing and respect its quota pressure / degraded state.
    pub(crate) tenant: u16,
}

/// Registry of live trees the [`crate::mmd`] daemon keeps healthy. See
/// the module docs for the registration contracts.
pub struct TreeRegistry<'e> {
    entries: Mutex<Vec<RegEntry<'e>>>,
    next_id: AtomicU64,
}

impl<'e> TreeRegistry<'e> {
    /// An empty registry.
    pub fn new() -> Self {
        TreeRegistry {
            entries: Mutex::new(Vec::new()),
            next_id: AtomicU64::new(1),
        }
    }

    /// Register `tree` for background compaction/rebalancing. Returns
    /// the id to [`TreeRegistry::deregister`] with.
    ///
    /// # Safety
    /// For the whole registration window the tree is accessed only
    /// through epoch-registered revalidating accessors
    /// ([`crate::trees::TreeView`] readers,
    /// [`crate::trees::TreeWriter`] seqlock writers): no raw leaf
    /// slices, no writes outside `TreeWriter`, no cross-thread cursors,
    /// no other migrator (module docs).
    pub unsafe fn register(&self, tree: &'e (dyn CompactTarget + 'e)) -> u64 {
        self.insert(tree, false, crate::pmem::tenant::DEFAULT_TENANT)
    }

    /// Register `tree` for compaction **and pressure-driven leaf
    /// eviction**.
    ///
    /// # Safety
    /// The [`TreeRegistry::register`] contract, plus: every accessor is
    /// **fault-capable** (`TreeView`/`TreeWriter` — their paths check
    /// the per-leaf swap word and fault evicted leaves back in), and a
    /// [`crate::pmem::LeafFaulter`] is installed on the tree before any
    /// accessor can hit an evicted leaf (module docs).
    pub unsafe fn register_evictable(&self, tree: &'e (dyn CompactTarget + 'e)) -> u64 {
        self.insert(tree, true, crate::pmem::tenant::DEFAULT_TENANT)
    }

    /// [`TreeRegistry::register`] with an owning tenant tag: the
    /// daemon's tenant-aware passes account relocations and report rows
    /// against `tenant`.
    ///
    /// # Safety
    /// The [`TreeRegistry::register`] contract.
    pub unsafe fn register_for_tenant(
        &self,
        tree: &'e (dyn CompactTarget + 'e),
        tenant: u16,
    ) -> u64 {
        self.insert(tree, false, tenant)
    }

    /// [`TreeRegistry::register_evictable`] with an owning tenant tag:
    /// evictions and restores of this tree go through the tenant's
    /// routed swap backing ([`crate::pmem::FaultQueue::route_tenant`]),
    /// its quota is credited/charged as leaves leave/reenter residency,
    /// and its degraded state parks the tree instead of wedging the
    /// whole daemon.
    ///
    /// # Safety
    /// The [`TreeRegistry::register_evictable`] contract. The installed
    /// faulter must route this tenant's traffic (a
    /// [`crate::pmem::TenantFaulter`] from
    /// [`crate::pmem::FaultQueue::scoped`]).
    pub unsafe fn register_evictable_for_tenant(
        &self,
        tree: &'e (dyn CompactTarget + 'e),
        tenant: u16,
    ) -> u64 {
        self.insert(tree, true, tenant)
    }

    fn insert(&self, tree: &'e (dyn CompactTarget + 'e), evictable: bool, tenant: u16) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.entries.lock().unwrap().push(RegEntry { id, tree, evictable, tenant });
        id
    }

    /// Remove a registration. Blocks until any in-flight compaction
    /// pass finishes (same mutex), so on return the daemon holds no
    /// reference to the tree. Panics if the tree still has swapped-out
    /// leaves — its bookkeeping names a limbo-retired block whose bytes
    /// live in swap, and dropping it would double-free the block
    /// (restore first; daemon shutdown does this automatically).
    pub fn deregister(&self, id: u64) {
        let mut g = self.entries.lock().unwrap();
        if let Some(i) = g.iter().position(|e| e.id == id) {
            let swapped = g[i].tree.swapped_leaves();
            assert!(
                swapped == 0,
                "deregistering tree {id} with {swapped} swapped-out leaves — restore first \
                 (MmdHandle::shutdown restores automatically)"
            );
            g.swap_remove(i);
        }
    }

    /// Registered trees.
    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    /// True when nothing is registered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total leaves currently swapped out across all registrations.
    pub fn swapped_out(&self) -> usize {
        self.entries.lock().unwrap().iter().map(|e| e.tree.swapped_leaves()).sum()
    }

    /// Resident (not yet swapped) leaves of evictable registrations —
    /// how much eviction could still reclaim. Policies use this to stop
    /// demanding eviction when nothing can satisfy it.
    pub fn evictable_resident(&self) -> usize {
        self.eviction_counts().1
    }

    /// `(swapped_out, evictable_resident)` under one lock — what the
    /// daemon feeds its policy every tick.
    pub fn eviction_counts(&self) -> (usize, usize) {
        let g = self.entries.lock().unwrap();
        let mut swapped = 0;
        let mut resident = 0;
        for e in g.iter() {
            let s = e.tree.swapped_leaves();
            swapped += s;
            if e.evictable {
                resident += e.tree.nleaves() - s;
            }
        }
        (swapped, resident)
    }

    /// Leaves currently swapped out across registrations owned by
    /// `tenant` (the per-tenant view of [`TreeRegistry::swapped_out`]).
    pub fn swapped_out_for(&self, tenant: u16) -> usize {
        self.entries
            .lock()
            .unwrap()
            .iter()
            .filter(|e| e.tenant == tenant)
            .map(|e| e.tree.swapped_leaves())
            .sum()
    }

    /// Resident (not swapped) leaves of `tenant`'s *evictable*
    /// registrations — what a quota-pressure eviction pass could still
    /// take from it. The daemon feeds the sum over pressured tenants to
    /// the policy so backpressure stops the moment a pressured tenant
    /// has nothing left to give.
    pub fn evictable_resident_for(&self, tenant: u16) -> usize {
        self.entries
            .lock()
            .unwrap()
            .iter()
            .filter(|e| e.evictable && e.tenant == tenant)
            .map(|e| e.tree.nleaves() - e.tree.swapped_leaves())
            .sum()
    }

    /// Total seqlock contention over all registered trees (writer heat
    /// — the daemon watches the per-tick delta to back off compaction
    /// while writers are hot; see `ThresholdPolicy`).
    pub fn lock_waits_total(&self) -> u64 {
        self.entries.lock().unwrap().iter().map(|e| e.tree.lock_waits()).sum()
    }

    /// Total read-side seq-bracket retries over all registered trees
    /// (reader pain — the daemon watches the per-tick delta and defers
    /// compaction while readers are being made to re-run; see
    /// `ThresholdPolicy`).
    pub fn seq_retries_total(&self) -> u64 {
        self.entries.lock().unwrap().iter().map(|e| e.tree.seq_retries()).sum()
    }

    /// Lock the entry list (compaction passes run under this guard; see
    /// the deregistration note in the module docs).
    pub(crate) fn lock(&self) -> MutexGuard<'_, Vec<RegEntry<'e>>> {
        self.entries.lock().unwrap()
    }
}

impl Default for TreeRegistry<'_> {
    fn default() -> Self {
        Self::new()
    }
}

impl std::fmt::Debug for TreeRegistry<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let g = self.entries.lock().unwrap();
        write!(f, "TreeRegistry {{ trees: {}, swapped_out: ", g.len())?;
        let swapped: usize = g.iter().map(|e| e.tree.swapped_leaves()).sum();
        write!(f, "{swapped} }}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::{BlockAllocator, SwapPool};

    #[test]
    fn register_deregister_roundtrip() {
        let a = BlockAllocator::new(1024, 64).unwrap();
        let t1: TreeArray<u32> = TreeArray::new(&a, 256 * 2).unwrap();
        let t2: TreeArray<u64> = TreeArray::new(&a, 128 * 3).unwrap();
        let reg = TreeRegistry::new();
        assert!(reg.is_empty());
        // SAFETY: nothing accesses the trees while registered here.
        let id1 = unsafe { reg.register(&t1) };
        let id2 = unsafe { reg.register_evictable(&t2) };
        assert_ne!(id1, id2);
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.swapped_out(), 0);
        {
            let g = reg.lock();
            assert!(!g[0].evictable);
            assert!(g[1].evictable);
            // The erased handles see the real trees.
            assert_eq!(g[0].tree.nleaves(), 2);
            assert_eq!(g[1].tree.nleaves(), 3);
            assert_eq!(g[0].tree.leaf_block(0), t1.leaf_block(0));
        }
        reg.deregister(id1);
        assert_eq!(reg.len(), 1);
        reg.deregister(id2);
        assert!(reg.is_empty());
        // Deregistering an unknown id is a no-op.
        reg.deregister(999);
    }

    #[test]
    fn erased_relocation_moves_the_real_leaf() {
        let a = BlockAllocator::new(1024, 64).unwrap();
        let mut t: TreeArray<u32> = TreeArray::new(&a, 256 * 2).unwrap();
        let data: Vec<u32> = (0..512u32).collect();
        t.copy_from_slice(&data).unwrap();
        let reg = TreeRegistry::new();
        // SAFETY: no accessors during the erased relocation below.
        let id = unsafe { reg.register(&t) };
        let dest = a.alloc().unwrap();
        {
            let g = reg.lock();
            // SAFETY: no readers at all; dest freshly allocated.
            unsafe { g[0].tree.relocate_leaf_to(1, dest) }.unwrap();
        }
        assert_eq!(t.leaf_block(1), dest);
        assert_eq!(t.to_vec(), data);
        reg.deregister(id);
        drop(reg);
        a.epoch().synchronize(&a);
        drop(t);
        assert_eq!(a.stats().allocated, 0);
    }

    #[test]
    fn erased_evict_restore_and_the_swapped_ledger() {
        let a = BlockAllocator::new(1024, 64).unwrap();
        let mut t: TreeArray<u32> = TreeArray::new(&a, 256 * 3).unwrap();
        let data: Vec<u32> = (0..(256 * 3) as u32).collect();
        t.copy_from_slice(&data).unwrap();
        let swap = SwapPool::anonymous(&a).unwrap();
        let reg = TreeRegistry::new();
        // SAFETY: accesses below are erased evict/restore + final
        // to_vec after everything is resident again.
        let id = unsafe { reg.register_evictable(&t) };
        {
            let g = reg.lock();
            // SAFETY: no accessor touches leaf 1 while it is out.
            unsafe { g[0].tree.evict_leaf(1, &swap) }.unwrap();
            assert_eq!(g[0].tree.swapped_leaves(), 1);
            assert_eq!(g[0].tree.leaf_swap_slot(1).is_some(), true);
        }
        assert_eq!(reg.swapped_out(), 1);
        assert_eq!(reg.evictable_resident(), 2);
        {
            let g = reg.lock();
            assert!(g[0].tree.restore_leaf(1, &swap).unwrap());
            assert!(!g[0].tree.restore_leaf(1, &swap).unwrap(), "second restore no-ops");
        }
        assert_eq!(reg.swapped_out(), 0);
        assert_eq!(t.to_vec(), data);
        reg.deregister(id);
    }

    #[test]
    #[should_panic(expected = "swapped-out leaves")]
    fn deregistering_with_swapped_leaves_panics() {
        let a = BlockAllocator::new(1024, 64).unwrap();
        let mut t: TreeArray<u32> = TreeArray::new(&a, 256 * 2).unwrap();
        t.copy_from_slice(&vec![0u32; 512]).unwrap();
        let swap = SwapPool::anonymous(&a).unwrap();
        let reg = TreeRegistry::new();
        // SAFETY: nothing accesses the tree while registered.
        let id = unsafe { reg.register_evictable(&t) };
        // SAFETY: no accessor touches the evicted leaf.
        unsafe { t.evict_leaf_via(0, &swap) }.unwrap();
        reg.deregister(id); // must panic: payload still in swap
    }
}
