//! Tree geometry and the address-trace model.
//!
//! All arithmetic for mapping a flat element index to the chain of
//! physical addresses a tree access touches. [`TreeArray`] uses
//! [`TreeGeometry`] for its real walks; the memsim experiments use
//! [`TreeTraceModel`] to generate the *addresses* a given tree access
//! would touch without materializing the tree (Table 2 goes to 64 GB).

use crate::error::{Error, Result};

/// Maximum supported tree depth (32 KB nodes: depth 4 ≈ 2 PB).
pub const MAX_DEPTH: u32 = 4;

/// Pure geometry of an arrays-as-trees structure.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TreeGeometry {
    /// Node/block size in bytes (32 KB in the paper).
    pub block_size: usize,
    /// Element size in bytes.
    pub elem_size: usize,
    /// Elements per leaf block.
    pub leaf_cap: usize,
    /// Children per interior node (block_size / 8-byte pointers).
    pub fanout: usize,
    /// Tree depth (1 = single leaf, no indirection).
    pub depth: u32,
    /// Element count.
    pub len: usize,
}

impl TreeGeometry {
    /// Geometry for `len` elements of `elem_size` bytes in `block_size`
    /// nodes. Errors if the array exceeds depth-4 capacity.
    pub fn new(block_size: usize, elem_size: usize, len: usize) -> Result<Self> {
        assert!(block_size.is_power_of_two() && elem_size.is_power_of_two());
        assert!(elem_size <= block_size);
        let leaf_cap = block_size / elem_size;
        let fanout = block_size / 8;
        let mut depth = 1u32;
        let mut cap = leaf_cap;
        while cap < len {
            depth += 1;
            if depth > MAX_DEPTH {
                return Err(Error::TooLarge {
                    len,
                    max: cap,
                    max_depth: MAX_DEPTH,
                });
            }
            cap = cap.saturating_mul(fanout);
        }
        Ok(TreeGeometry {
            block_size,
            elem_size,
            leaf_cap,
            fanout,
            depth,
            len: len.max(1),
        })
    }

    /// Max elements addressable at `depth` with this node geometry.
    pub fn capacity_at_depth(&self, depth: u32) -> usize {
        let mut cap = self.leaf_cap;
        for _ in 1..depth {
            cap = cap.saturating_mul(self.fanout);
        }
        cap
    }

    /// Number of leaf blocks.
    #[inline]
    pub fn nleaves(&self) -> usize {
        self.len.div_ceil(self.leaf_cap)
    }

    /// Elements covered by one subtree hanging off a node at `level`
    /// (level 0 = root; level depth-1 = leaf, covering `leaf_cap`).
    #[inline]
    pub fn subtree_elems(&self, level: u32) -> usize {
        let mut cap = self.leaf_cap;
        for _ in level..self.depth - 1 {
            cap = cap.saturating_mul(self.fanout);
        }
        cap
    }

    /// Leaf index of element `i`.
    #[inline]
    pub fn leaf_of(&self, i: usize) -> usize {
        i / self.leaf_cap
    }

    /// Nodes at interior `level` (root = level 0). Leaves are level
    /// `depth-1`.
    pub fn nodes_at_level(&self, level: u32) -> usize {
        debug_assert!(level < self.depth);
        // Walk up from the leaf count.
        let mut n = self.nleaves();
        for _ in level..self.depth - 1 {
            n = n.div_ceil(self.fanout);
        }
        n
    }

    /// Total blocks (interior + leaf) the tree occupies.
    pub fn total_blocks(&self) -> usize {
        (0..self.depth).map(|l| self.nodes_at_level(l)).sum()
    }

    /// Child slot taken at `level` on the path to element `i`.
    #[inline]
    pub fn child_slot(&self, level: u32, i: usize) -> usize {
        (i / self.subtree_elems(level + 1)) % self.fanout
    }
}

/// Address-trace model: the physical addresses an access touches, without
/// any memory backing. Blocks are numbered root-first, level by level,
/// then placed at `base_addr + block_index * block_size` — matching how
/// `TreeArray` would lay out in a fresh allocator pool.
#[derive(Clone, Debug)]
pub struct TreeTraceModel {
    /// Geometry underneath.
    pub geo: TreeGeometry,
    /// Physical base address of block 0 (the root).
    pub base_addr: u64,
    /// Block-index offset of each level's first node.
    level_base: [u64; MAX_DEPTH as usize],
}

impl TreeTraceModel {
    /// Model a tree of `len` elements at physical `base_addr`.
    pub fn new(geo: TreeGeometry, base_addr: u64) -> Self {
        let mut level_base = [0u64; MAX_DEPTH as usize];
        let mut acc = 0u64;
        for l in 0..geo.depth {
            level_base[l as usize] = acc;
            acc += geo.nodes_at_level(l) as u64;
        }
        TreeTraceModel {
            geo,
            base_addr,
            level_base,
        }
    }

    /// Physical address of the `slot`-th 8-byte pointer in the
    /// `node`-th interior node of `level`.
    #[inline]
    pub fn interior_addr(&self, level: u32, node: usize, slot: usize) -> u64 {
        self.base_addr
            + (self.level_base[level as usize] + node as u64) * self.geo.block_size as u64
            + (slot as u64) * 8
    }

    /// Physical address of element `i`'s data byte(s) in its leaf.
    #[inline]
    pub fn leaf_elem_addr(&self, i: usize) -> u64 {
        let leaf = self.geo.leaf_of(i);
        let off = (i % self.geo.leaf_cap) * self.geo.elem_size;
        self.base_addr
            + (self.level_base[(self.geo.depth - 1) as usize] + leaf as u64)
                * self.geo.block_size as u64
            + off as u64
    }

    /// The naive access path for element `i` (Figure 1): one pointer
    /// load per interior level, then the data load. Returns addresses in
    /// access order into `out` (cleared first); `out.len() == depth`.
    pub fn access_path(&self, i: usize, out: &mut Vec<u64>) {
        out.clear();
        let mut node = 0usize;
        for level in 0..self.geo.depth - 1 {
            let slot = self.geo.child_slot(level, i);
            out.push(self.interior_addr(level, node, slot));
            node = node * self.geo.fanout + slot;
        }
        out.push(self.leaf_elem_addr(i));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::forall;

    const BS: usize = 32 * 1024;

    #[test]
    fn depth_matches_paper_footnote() {
        // 32 KB nodes: depth-3 addresses ~536 GB, depth-4 ~2 PB (f64).
        let g = TreeGeometry::new(BS, 8, 1).unwrap();
        let d3_bytes = g.capacity_at_depth(3) as u128 * 8;
        let d4_bytes = g.capacity_at_depth(4) as u128 * 8;
        assert_eq!(d3_bytes, 512u128 << 30); // 512 GiB ≈ "about 536 GB"
        assert_eq!(d4_bytes, 2u128 << 50); // 2 PiB ≈ "2 PB"
    }

    #[test]
    fn table2_depths() {
        // Table 2 caption: 4 KB arrays fit depth-1 trees, 4 MB depth-2,
        // all larger (4–64 GB) depth-3. Elements are 4-byte (f32/i32).
        for (bytes, want) in [
            (4usize << 10, 1u32),
            (4 << 20, 2),
            (4usize << 30, 3),
            (64usize << 30, 3),
        ] {
            let g = TreeGeometry::new(BS, 4, bytes / 4).unwrap();
            assert_eq!(g.depth, want, "{} bytes", bytes);
        }
    }

    #[test]
    fn too_large_rejected() {
        // > depth-4 capacity must error, not misbehave.
        let g = TreeGeometry::new(256, 8, 1).unwrap();
        let max = g.capacity_at_depth(4);
        assert!(TreeGeometry::new(256, 8, max + 1).is_err());
    }

    #[test]
    fn nodes_at_level_root_is_one() {
        let g = TreeGeometry::new(BS, 4, 1 << 30).unwrap(); // 4 GB, depth 3
        assert_eq!(g.nodes_at_level(0), 1);
        assert_eq!(g.nodes_at_level(g.depth - 1), g.nleaves());
    }

    #[test]
    fn access_path_depth1_is_single_load() {
        let g = TreeGeometry::new(BS, 4, 100).unwrap();
        assert_eq!(g.depth, 1);
        let m = TreeTraceModel::new(g, 0x1000);
        let mut path = Vec::new();
        m.access_path(7, &mut path);
        assert_eq!(path.len(), 1);
        assert_eq!(path[0], 0x1000 + 7 * 4);
    }

    #[test]
    fn access_path_lengths_equal_depth() {
        for len in [100usize, 1 << 20, 1 << 28] {
            let g = TreeGeometry::new(BS, 4, len).unwrap();
            let m = TreeTraceModel::new(g, 0);
            let mut path = Vec::new();
            m.access_path(len - 1, &mut path);
            assert_eq!(path.len(), g.depth as usize);
        }
    }

    #[test]
    fn prop_distinct_elements_distinct_leaf_addrs() {
        forall(40, |gen| {
            let len = gen.usize_in(2, 1 << 20);
            let g = TreeGeometry::new(BS, 4, len).unwrap();
            let m = TreeTraceModel::new(g, 0);
            let i = gen.usize_in(0, len - 1);
            let j = gen.usize_in(0, len - 1);
            if i != j {
                assert_ne!(m.leaf_elem_addr(i), m.leaf_elem_addr(j));
            }
        });
    }

    #[test]
    fn prop_leaf_addrs_within_tree_extent() {
        forall(40, |gen| {
            let len = gen.usize_in(1, 1 << 22);
            let g = TreeGeometry::new(BS, 4, len).unwrap();
            let m = TreeTraceModel::new(g, 4096);
            let extent = g.total_blocks() as u64 * BS as u64;
            let i = gen.usize_in(0, len - 1);
            let a = m.leaf_elem_addr(i);
            assert!(a >= 4096 && a < 4096 + extent);
        });
    }

    #[test]
    fn prop_sequential_elems_same_leaf_share_block() {
        forall(40, |gen| {
            let len = gen.usize_in(2, 1 << 20);
            let g = TreeGeometry::new(BS, 4, len).unwrap();
            let m = TreeTraceModel::new(g, 0);
            let i = gen.usize_in(0, len - 2);
            if g.leaf_of(i) == g.leaf_of(i + 1) {
                assert_eq!(m.leaf_elem_addr(i) + 4, m.leaf_elem_addr(i + 1));
            }
        });
    }
}
