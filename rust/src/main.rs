//! `nvm` — the leader binary: runs the paper's experiments, serves
//! batched pricing requests through the PJRT runtime, and prints
//! environment info.

use std::path::Path;

use nvm::cli::Cli;
use nvm::coordinator::{list_experiments, run_experiment, run_experiment_recorded, ExpConfig};
use nvm::runtime::Engine;
use nvm::telemetry::report::{render_dat, render_results};
use nvm::telemetry::{DiffReport, ResultsFile, ResultsWriter};
use nvm::workloads::CostModel;

fn main() {
    let cli = match Cli::parse(std::env::args().skip(1)) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match cli.command() {
        Some("list") => cmd_list(),
        Some("run") => cmd_run(&cli),
        Some("report") => cmd_report(&cli),
        Some("diff") => cmd_diff(&cli),
        Some("merge") => cmd_merge(&cli),
        Some("info") => cmd_info(),
        Some("serve") => cmd_serve(&cli),
        _ => {
            print_usage();
            0
        }
    };
    std::process::exit(code);
}

fn print_usage() {
    println!(
        "nvm — software-based memory management without virtual memory\n\
         \n\
         USAGE:\n\
           nvm list                          list experiments\n\
           nvm run <experiment|all> [flags]  run and print paper tables\n\
           nvm report <results.json> [--dat] render a results file (table or gnuplot .dat)\n\
           nvm diff <old.json> <new.json>    CI-overlap regression verdicts (nonzero exit\n\
                                             on regression; --soft reports only)\n\
           nvm merge <out.json> <in.json>... merge results files (--label NAME)\n\
           nvm serve [--requests N]          serve blackscholes blocks via PJRT\n\
           nvm info                          runtime/artifact info\n\
         \n\
         FLAGS (run):\n\
           --sample N     simulated accesses per data point (default 2000000)\n\
           --quick        200k samples (fast smoke run)\n\
           --threads N    sweep parallelism\n\
           --seed N       workload RNG seed\n\
           --markdown     print tables as markdown\n\
           --json PATH    also write a machine-readable results file\n\
           --kv-rate R    kv-serve open-loop arrival rate in ops/s (default 25000)"
    );
}

fn cmd_list() -> i32 {
    for e in list_experiments() {
        println!("{:22} {}", e.name, e.description);
    }
    0
}

fn cmd_run(cli: &Cli) -> i32 {
    let name = match cli.positional.get(1) {
        Some(n) => n.clone(),
        None => {
            eprintln!("error: `nvm run <experiment>`; see `nvm list`");
            return 2;
        }
    };
    let mut cfg = if cli.flag_bool("quick") {
        ExpConfig::quick()
    } else {
        ExpConfig::default()
    };
    cfg.sample = cli.flag_u64("sample", cfg.sample).unwrap_or(cfg.sample);
    cfg.threads = cli.flag_u64("threads", cfg.threads as u64).unwrap_or(8) as usize;
    cfg.seed = cli.flag_u64("seed", cfg.seed).unwrap_or(cfg.seed);
    cfg.model = CostModel::default();
    match cli.flag_f64("kv-rate", 0.0) {
        Ok(rate) if rate > 0.0 => std::env::set_var("NVM_KV_RATE", format!("{rate}")),
        Ok(_) => {}
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    }
    println!(
        "threads: {} (default would be {}: available cores, fallback 4, capped at 8)",
        cfg.threads,
        nvm::coordinator::pool::default_threads()
    );
    let json_path = cli.flag_str("json").map(str::to_string);
    let run = match &json_path {
        Some(_) => run_experiment_recorded(&name, &cfg),
        None => run_experiment(&name, &cfg).map(|tables| (tables, Vec::new())),
    };
    match run {
        Ok((tables, records)) => {
            for t in tables {
                if cli.flag_bool("markdown") {
                    println!("{}", t.to_markdown());
                } else {
                    println!("{t}");
                }
            }
            if let Some(path) = json_path {
                let mut w = ResultsWriter::new(&format!("run-{name}"));
                for r in records {
                    w.add(r);
                }
                if let Err(e) = w.save(Path::new(&path)) {
                    eprintln!("error: {e}");
                    return 1;
                }
                println!("results: wrote {path}");
            }
            0
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

/// Render a results file for humans (default) or gnuplot (`--dat`).
fn cmd_report(cli: &Cli) -> i32 {
    let Some(path) = cli.positional.get(1) else {
        eprintln!("error: `nvm report <results.json>`");
        return 2;
    };
    match ResultsFile::load(Path::new(path)) {
        Ok(file) => {
            if cli.flag_bool("dat") {
                print!("{}", render_dat(&file));
            } else {
                print!("{}", render_results(&file));
            }
            0
        }
        Err(e) => {
            // Schema/parse problems are hard errors (exit 2), per the
            // CI contract: a malformed results file must never pass.
            eprintln!("error: {e}");
            2
        }
    }
}

/// Compare two results files; exit 1 on regression (0 with `--soft`),
/// 2 on schema errors.
fn cmd_diff(cli: &Cli) -> i32 {
    let (Some(old_path), Some(new_path)) = (cli.positional.get(1), cli.positional.get(2)) else {
        eprintln!("error: `nvm diff <old.json> <new.json>`");
        return 2;
    };
    let old = match ResultsFile::load(Path::new(old_path)) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    let new = match ResultsFile::load(Path::new(new_path)) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    let report = DiffReport::compare(&old, &new);
    print!("{report}");
    if report.regressions() > 0 && !cli.flag_bool("soft") {
        1
    } else {
        0
    }
}

/// Merge per-bench results files into one (CI folds the bench-suite
/// drops into `BENCH_ci.json` this way).
fn cmd_merge(cli: &Cli) -> i32 {
    let Some(out_path) = cli.positional.get(1) else {
        eprintln!("error: `nvm merge <out.json> <in.json>...`");
        return 2;
    };
    let inputs = &cli.positional[2..];
    if inputs.is_empty() {
        eprintln!("error: `nvm merge <out.json> <in.json>...`");
        return 2;
    }
    let mut parts = Vec::new();
    for p in inputs {
        match ResultsFile::load(Path::new(p)) {
            Ok(f) => parts.push(f),
            Err(e) => {
                eprintln!("error: {e}");
                return 2;
            }
        }
    }
    let label = cli.flag_str("label").unwrap_or("merged");
    let merged = match ResultsFile::merge(label, &parts) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    match merged.save(Path::new(out_path)) {
        Ok(()) => {
            println!(
                "merged {} record(s) from {} file(s) into {out_path}",
                merged.records.len(),
                parts.len()
            );
            0
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_info() -> i32 {
    match Engine::new() {
        Ok(engine) => {
            println!("platform: {}", engine.platform());
            println!("artifacts:");
            for n in engine.artifacts().names() {
                println!("  {n}");
            }
            0
        }
        Err(e) => {
            eprintln!("error: {e} (run `make artifacts` first)");
            1
        }
    }
}

/// A tiny request loop: prices N random 32 KB blocks through the AOT
/// latency artifact and reports throughput — the serving-shaped
/// demonstration that Python is not on the request path.
fn cmd_serve(cli: &Cli) -> i32 {
    use nvm::coordinator::BlockBatcher;
    use nvm::testutil::Rng;
    use nvm::BLOCK_ELEMS_F32 as BELE;

    let requests = cli.flag_u64("requests", 64).unwrap_or(64);
    let engine = match Engine::new() {
        Ok(e) => e,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    if let Err(e) = engine.warm("bs_blocked_1x8192") {
        eprintln!("error compiling artifact: {e}");
        return 1;
    }
    let mut batcher = BlockBatcher::new(&engine);
    let mut rng = Rng::new(7);
    let mut lat = Vec::with_capacity(requests as usize);
    let t0 = std::time::Instant::now();
    for _ in 0..requests {
        let spot: Vec<f32> = (0..BELE).map(|_| rng.f32_range(5.0, 200.0)).collect();
        let strike: Vec<f32> = (0..BELE).map(|_| rng.f32_range(5.0, 200.0)).collect();
        let tmat: Vec<f32> = (0..BELE).map(|_| rng.f32_range(0.05, 3.0)).collect();
        let r0 = std::time::Instant::now();
        match batcher.price_one_block(&spot, &strike, &tmat, 0.03, 0.25) {
            Ok((call, _put)) => {
                std::hint::black_box(call[0]);
                lat.push(r0.elapsed());
            }
            Err(e) => {
                eprintln!("request failed: {e}");
                return 1;
            }
        }
    }
    let total = t0.elapsed();
    lat.sort_unstable();
    let p50 = lat[lat.len() / 2];
    let p99 = lat[(lat.len() * 99 / 100).min(lat.len() - 1)];
    println!(
        "served {requests} block requests ({} options) in {:.3}s",
        requests * BELE as u64,
        total.as_secs_f64()
    );
    println!(
        "throughput: {:.0} options/s   p50 {:.3}ms   p99 {:.3}ms",
        requests as f64 * BELE as f64 / total.as_secs_f64(),
        p50.as_secs_f64() * 1e3,
        p99.as_secs_f64() * 1e3
    );
    0
}
