//! PJRT engine: compile-once, execute-many over the CPU client.
//!
//! Follows the /opt/xla-example pattern: HLO **text** →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. Executables are cached per artifact
//! name; compilation happens at most once per variant per process.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::error::{Error, Result};
use crate::runtime::Artifacts;

/// A typed input literal for execution.
pub enum Input<'a> {
    /// f32 buffer reshaped to `shape`.
    F32(&'a [f32], Vec<i64>),
    /// i32 buffer reshaped to `shape`.
    I32(&'a [i32], Vec<i64>),
    /// f32 scalar.
    ScalarF32(f32),
}

impl Input<'_> {
    fn to_literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            Input::F32(data, shape) => {
                if shape.len() == 1 {
                    xla::Literal::vec1(data)
                } else {
                    xla::Literal::vec1(data).reshape(shape)?
                }
            }
            Input::I32(data, shape) => {
                if shape.len() == 1 {
                    xla::Literal::vec1(data)
                } else {
                    xla::Literal::vec1(data).reshape(shape)?
                }
            }
            Input::ScalarF32(v) => xla::Literal::scalar(*v),
        })
    }
}

/// The compile-once / run-many engine around a PJRT CPU client.
pub struct Engine {
    client: xla::PjRtClient,
    artifacts: Artifacts,
    cache: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
    compiles: Mutex<u64>,
}

impl Engine {
    /// Create an engine over discovered artifacts.
    pub fn new() -> Result<Self> {
        Self::with_artifacts(Artifacts::discover()?)
    }

    /// Create an engine over a specific artifact set.
    pub fn with_artifacts(artifacts: Artifacts) -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine {
            client,
            artifacts,
            cache: Mutex::new(HashMap::new()),
            compiles: Mutex::new(0),
        })
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Artifact registry.
    pub fn artifacts(&self) -> &Artifacts {
        &self.artifacts
    }

    /// Number of compilations performed (tests assert compile-once).
    pub fn compile_count(&self) -> u64 {
        *self.compiles.lock().unwrap()
    }

    /// Ensure `name` is compiled (warm the cache ahead of timing runs).
    pub fn warm(&self, name: &str) -> Result<()> {
        self.with_executable(name, |_| Ok(()))
    }

    fn with_executable<R>(
        &self,
        name: &str,
        f: impl FnOnce(&xla::PjRtLoadedExecutable) -> Result<R>,
    ) -> Result<R> {
        let mut cache = self.cache.lock().unwrap();
        if !cache.contains_key(name) {
            let path = self.artifacts.hlo_path(name)?;
            let proto = xla::HloModuleProto::from_text_file(&path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            *self.compiles.lock().unwrap() += 1;
            cache.insert(name.to_string(), exe);
        }
        f(cache.get(name).unwrap())
    }

    /// Execute artifact `name` with `inputs`; returns the flattened
    /// output tuple as f32 vectors.
    pub fn run_f32(&self, name: &str, inputs: &[Input<'_>]) -> Result<Vec<Vec<f32>>> {
        let outs = self.run_raw(name, inputs)?;
        outs.into_iter()
            .map(|l| l.to_vec::<f32>().map_err(Error::from))
            .collect()
    }

    /// Execute artifact `name`; returns the output tuple as i32 vectors.
    pub fn run_i32(&self, name: &str, inputs: &[Input<'_>]) -> Result<Vec<Vec<i32>>> {
        let outs = self.run_raw(name, inputs)?;
        outs.into_iter()
            .map(|l| l.to_vec::<i32>().map_err(Error::from))
            .collect()
    }

    fn run_raw(&self, name: &str, inputs: &[Input<'_>]) -> Result<Vec<xla::Literal>> {
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|i| i.to_literal())
            .collect::<Result<_>>()?;
        self.with_executable(name, |exe| {
            let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
            // aot.py lowers with return_tuple=True: always a tuple.
            Ok(result.to_tuple()?)
        })
    }
}

// The engine is used from the coordinator's worker threads.
// SAFETY: the xla crate's client/executable wrap thread-safe PJRT
// objects; the cache is mutex-guarded.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}
