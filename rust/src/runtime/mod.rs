//! The PJRT execution path: load AOT artifacts, compile once, execute
//! from the Rust hot path. Python only ever ran at build time
//! (`make artifacts`).

mod artifacts;
mod pjrt;

pub use artifacts::{ArtifactSpec, Artifacts};
pub use pjrt::{Engine, Input};
