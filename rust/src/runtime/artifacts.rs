//! Artifact discovery: locate `artifacts/` and parse `manifest.txt`
//! (written by `python/compile/aot.py`).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

/// One artifact's signature: argument dtypes and shapes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArtifactSpec {
    /// Variant name (file stem).
    pub name: String,
    /// Per-argument `(dtype, shape)` as recorded in the manifest, e.g.
    /// `("float32", vec![256, 8192])`.
    pub args: Vec<(String, Vec<usize>)>,
}

/// The artifact directory + parsed manifest.
#[derive(Clone, Debug)]
pub struct Artifacts {
    dir: PathBuf,
    specs: HashMap<String, ArtifactSpec>,
}

impl Artifacts {
    /// Discover artifacts: `$NVM_ARTIFACTS` if set, else `./artifacts`,
    /// else `../artifacts` (for tests running under `target/`).
    pub fn discover() -> Result<Self> {
        if let Ok(dir) = std::env::var("NVM_ARTIFACTS") {
            return Self::open(dir);
        }
        for cand in ["artifacts", "../artifacts"] {
            if Path::new(cand).join("manifest.txt").exists() {
                return Self::open(cand);
            }
        }
        Err(Error::Artifact(
            "artifacts/manifest.txt not found; run `make artifacts` (or set NVM_ARTIFACTS)".into(),
        ))
    }

    /// Open a specific artifact directory.
    pub fn open(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest)
            .map_err(|e| Error::Artifact(format!("{}: {e}", manifest.display())))?;
        let mut specs = HashMap::new();
        for line in text.lines().filter(|l| !l.trim().is_empty()) {
            let spec = Self::parse_line(line)?;
            specs.insert(spec.name.clone(), spec);
        }
        Ok(Artifacts { dir, specs })
    }

    /// Parse one manifest line: `name dtype[d0,d1];dtype[d0]` …
    fn parse_line(line: &str) -> Result<ArtifactSpec> {
        let bad = |m: &str| Error::Artifact(format!("manifest line {line:?}: {m}"));
        let (name, sig) = line
            .split_once(' ')
            .ok_or_else(|| bad("missing signature"))?;
        let mut args = Vec::new();
        for part in sig.split(';') {
            let (dtype, rest) = part
                .split_once('[')
                .ok_or_else(|| bad("missing '[' in arg"))?;
            let dims = rest.trim_end_matches(']');
            let shape: Vec<usize> = if dims.is_empty() {
                vec![]
            } else {
                dims.split(',')
                    .map(|d| d.parse().map_err(|_| bad("bad dim")))
                    .collect::<Result<_>>()?
            };
            args.push((dtype.to_string(), shape));
        }
        Ok(ArtifactSpec {
            name: name.to_string(),
            args,
        })
    }

    /// Path of the HLO text file for `name`.
    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        if !self.specs.contains_key(name) {
            return Err(Error::Artifact(format!(
                "unknown artifact {name:?} (have: {:?})",
                self.names()
            )));
        }
        let p = self.dir.join(format!("{name}.hlo.txt"));
        if !p.exists() {
            return Err(Error::Artifact(format!("{} missing on disk", p.display())));
        }
        Ok(p)
    }

    /// Spec for `name`.
    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.specs.get(name)
    }

    /// All artifact names, sorted.
    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.specs.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_line() {
        let s = Artifacts::parse_line("bs_blocked_1x8192 float32[1,8192];float32[]").unwrap();
        assert_eq!(s.name, "bs_blocked_1x8192");
        assert_eq!(s.args[0], ("float32".into(), vec![1, 8192]));
        assert_eq!(s.args[1], ("float32".into(), vec![]));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Artifacts::parse_line("no_signature_here").is_err());
        assert!(Artifacts::parse_line("x float32 8192").is_err());
    }

    #[test]
    fn open_missing_dir_fails() {
        assert!(Artifacts::open("/nonexistent/path").is_err());
    }
}
