//! # nvm — software-based memory management without virtual memory
//!
//! A reproduction of Zagieboylo, Suh & Myers, *"The Cost of Software-Based
//! Memory Management Without Virtual Memory"* (2020), built as a
//! three-layer Rust + JAX/Pallas stack (see `DESIGN.md`).
//!
//! The crate provides:
//!
//! * [`pmem`] — the paper's §3 OS memory manager behind the
//!   [`pmem::BlockAlloc`] trait: every consumer (trees, stacks, regions,
//!   workloads, the coordinator) is generic over the allocator policy.
//!   Two policies ship: [`pmem::BlockAllocator`], the single-mutex LIFO
//!   baseline, and [`pmem::ShardedAllocator`], per-shard atomic free
//!   bitmaps with thread-affine shards and cross-shard stealing for
//!   multi-threaded workloads (fixed-size blocks, default 32 KB, in
//!   both).
//! * [`trees`] — §3.2 "arrays as trees": discontiguous arrays built from
//!   allocator blocks, with a full software translation-cache stack
//!   (§4.4): the Figure 2 iterator optimization generalized to a
//!   set-associative leaf-TLB ([`trees::LeafTlb`]), an O(1) flat
//!   leaf-table mode, generation-based shootdown so relocated leaves
//!   are never read stale, batched sort-and-run accessors, and
//!   [`trees::TreeView`] — `Send` shared read views with *per-thread*
//!   TLBs plus arena-epoch quiescence ([`pmem::ArenaEpoch`]), so many
//!   threads read one tree lock-free while leaves relocate under them —
//!   and [`trees::TreeWriter`], the concurrent write side: per-leaf
//!   **seqlocks** let M writers, N readers, and background relocation
//!   share one tree with no global lock (readers retry seq brackets,
//!   relocation takes the same leaf lock, so writes are never torn or
//!   lost).
//! * [`mmd`] — the background memory-management daemon: fragmentation
//!   telemetry over any [`pmem::BlockAlloc`] pool, a pluggable policy
//!   loop, and a compactor that relocates/evicts/restores leaves of
//!   registered live trees ([`trees::TreeRegistry`]) through the
//!   epoch-deferred relocation machinery — keeping the arena healthy
//!   while [`trees::TreeView`] readers keep reading.
//! * [`stack`] — §3.1 split stacks: a segmented-stack frame machine plus
//!   the per-benchmark call-profile overhead model behind Figure 3.
//! * [`memsim`] — the virtual-memory-vs-physical cost model: a
//!   cycle-approximate TLB / page-table-walk / cache / DRAM simulator
//!   calibrated to the paper's i7-7700 testbed. This substitutes for the
//!   paper's 1 GB-huge-page "physical addressing" hardware trick.
//! * [`workloads`] — the evaluation workloads: linear/strided scans,
//!   GUPS, red–black tree, Black-Scholes, a deepsjeng-like hash probe,
//!   and the recursive-Fibonacci stack microbenchmark. All tree-layout
//!   variants accept any [`pmem::BlockAlloc`] implementation.
//! * [`kv`] — **pallas-kv**, the first end-to-end service consumer of
//!   the stack: an etcd-like keyspace (get/put/delete/range plus a
//!   bounded watch event ring) whose values live in [`trees::TreeArray`]
//!   cells behind seqlock-stamped out-of-place commits, served over a
//!   pluggable [`kv::Transport`] (in-process channels by default, TCP
//!   behind the `net` feature) and driven by an open-loop load
//!   generator with zipfian/uniform key mixes recording per-op latency
//!   into [`telemetry::LogHistogram`] — mmd compaction, eviction, and
//!   software page faults all running underneath one latency SLO.
//! * [`coordinator`] — experiment registry, runner, thread pool, block
//!   batcher, and paper-style report formatting. Includes the
//!   multi-threaded experiments the sharded allocator enables
//!   (`concurrent-gups`, `parallel-blackscholes`, `ablation-alloc`) and
//!   the translation-amortization comparison (`batched-workloads`).
//! * [`runtime`] — the PJRT execution path: loads `artifacts/*.hlo.txt`
//!   (AOT-lowered JAX/Pallas) and runs them from Rust; Python is never on
//!   the request path.
//! * [`telemetry`] — the unified measurement surface: a streaming stat
//!   engine with hot-path log-scale histograms, every subsystem stats
//!   struct behind one [`telemetry::MetricSource`] trait, and the
//!   machine-readable `BENCH_*.json` results pipeline (schema, writer,
//!   `report`/`diff` rendering with CI-overlap regression verdicts).
//!
//! ## Quickstart
//!
//! Data structures take any allocator implementing
//! [`pmem::BlockAlloc`]; pick the mutex baseline for simplicity or the
//! sharded allocator when threads share the pool:
//!
//! ```no_run
//! use nvm::pmem::{BlockAlloc, BlockAllocator, ShardedAllocator};
//! use nvm::trees::TreeArray;
//!
//! // Single-threaded: the mutex baseline.
//! let alloc = BlockAllocator::with_capacity_bytes(1 << 24).unwrap();
//! let mut arr: TreeArray<f32> = TreeArray::new(&alloc, 20_000).unwrap();
//! arr.set(12_345, 1.5).unwrap();
//! assert_eq!(arr.get(12_345).unwrap(), 1.5);
//!
//! // Thread-shared: the sharded lock-free pool, same consumer code.
//! let shared = ShardedAllocator::with_capacity_bytes(1 << 24).unwrap();
//! std::thread::scope(|s| {
//!     for t in 0..4 {
//!         let shared = &shared;
//!         s.spawn(move || {
//!             let mut local: TreeArray<u64, ShardedAllocator> =
//!                 TreeArray::new(shared, 100_000).unwrap();
//!             local.set(t, t as u64).unwrap();
//!         });
//!     }
//! });
//! assert_eq!(shared.stats().allocated, 0); // trees released their blocks
//! ```
//!
//! Generic code states one bound and runs on either policy:
//!
//! ```no_run
//! use nvm::pmem::BlockAlloc;
//! use nvm::trees::TreeArray;
//!
//! fn sum<A: BlockAlloc>(t: &TreeArray<'_, f32, A>) -> f64 {
//!     t.iter().map(|v| v as f64).sum()
//! }
//! ```

pub mod bench_utils;
pub mod cli;
pub mod coordinator;
pub mod error;
pub mod kv;
pub mod memsim;
pub mod mmd;
pub mod pmem;
pub mod runtime;
pub mod stack;
pub mod telemetry;
pub mod testutil;
pub mod trees;
pub mod workloads;

pub use error::{Error, Result};

/// The paper's block size: 32 KB, the fixed allocation unit of §3.
pub const BLOCK_SIZE: usize = 32 * 1024;

/// f32 elements per 32 KB block (= the Pallas kernel tile, 8192).
pub const BLOCK_ELEMS_F32: usize = BLOCK_SIZE / 4;
