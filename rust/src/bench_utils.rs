//! Measurement harness for the `harness = false` benches (criterion is
//! unavailable offline; this reimplements its core discipline: warmup,
//! fixed-iteration sampling, mean/σ/min reporting).

use std::time::{Duration, Instant};

use crate::telemetry::{Direction, MetricRecord};

/// Summary statistics for one measured benchmark.
#[derive(Clone, Debug)]
pub struct Sample {
    /// Human label.
    pub name: String,
    /// Per-iteration mean.
    pub mean: Duration,
    /// Per-iteration sample standard deviation.
    pub stddev: Duration,
    /// Fastest iteration.
    pub min: Duration,
    /// Iterations measured (after warmup).
    pub iters: u32,
    /// Raw per-iteration times — the fields above are derived from
    /// these; the results pipeline records them so downstream diffs
    /// can recompute CIs instead of trusting a point estimate.
    pub times: Vec<Duration>,
}

impl Sample {
    /// Mean in nanoseconds.
    pub fn mean_ns(&self) -> f64 {
        self.mean.as_secs_f64() * 1e9
    }

    /// The raw iterations as a results-schema metric, each iteration's
    /// nanoseconds mapped through `f` (per-op ns, Mop/s, ...).
    pub fn metric_with(
        &self,
        name: &str,
        unit: &str,
        direction: Direction,
        f: impl Fn(f64) -> f64,
    ) -> MetricRecord {
        let samples = self.times.iter().map(|t| f(t.as_secs_f64() * 1e9)).collect();
        MetricRecord::from_samples(name, unit, direction, samples)
    }

    /// Per-operation latency metric: iteration ns × `scale`
    /// (`1.0 / ops_per_iter` for ns/op), lower is better.
    pub fn metric_ns(&self, name: &str, scale: f64) -> MetricRecord {
        self.metric_with(name, "ns", Direction::Lower, |ns| ns * scale)
    }
}

impl std::fmt::Display for Sample {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:40} {:>12.3} ms  ±{:>8.3} ms  (min {:>10.3} ms, n={})",
            self.name,
            self.mean.as_secs_f64() * 1e3,
            self.stddev.as_secs_f64() * 1e3,
            self.min.as_secs_f64() * 1e3,
            self.iters
        )
    }
}

/// Measure `f`, returning per-iteration stats.
///
/// Runs `warmup` unrecorded iterations, then `iters` timed ones. `f`
/// should return something observable to stop the optimizer from deleting
/// the work; its result is passed through `std::hint::black_box`.
pub fn bench<T>(name: &str, warmup: u32, iters: u32, mut f: impl FnMut() -> T) -> Sample {
    assert!(iters >= 1);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed());
    }
    summarize(name, times)
}

/// Adaptive variant: keeps iterating until `budget` wall time is spent
/// (at least 3 iterations), for workloads whose runtime is unknown.
pub fn bench_for<T>(name: &str, budget: Duration, mut f: impl FnMut() -> T) -> Sample {
    std::hint::black_box(f()); // warmup
    let mut times = Vec::new();
    let start = Instant::now();
    while times.len() < 3 || start.elapsed() < budget {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed());
        if times.len() >= 10_000 {
            break;
        }
    }
    summarize(name, times)
}

fn summarize(name: &str, times: Vec<Duration>) -> Sample {
    let n = times.len() as f64;
    let mean_s = times.iter().map(|t| t.as_secs_f64()).sum::<f64>() / n;
    let var = if times.len() > 1 {
        times
            .iter()
            .map(|t| (t.as_secs_f64() - mean_s).powi(2))
            .sum::<f64>()
            / (n - 1.0)
    } else {
        0.0
    };
    Sample {
        name: name.to_string(),
        mean: Duration::from_secs_f64(mean_s),
        stddev: Duration::from_secs_f64(var.sqrt()),
        min: *times.iter().min().unwrap(),
        iters: times.len() as u32,
        times,
    }
}

/// Print a bench-section header (keeps bench output grep-able).
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Print a formatted ratio row: `label: num/den = ratio`.
pub fn ratio_row(label: &str, num: f64, den: f64) {
    println!("{label:40} {:>10.3} / {:>10.3} = {:>6.2}x", num, den, num / den);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_iters() {
        let s = bench("noop", 1, 5, || 42u64);
        assert_eq!(s.iters, 5);
        assert!(s.mean <= Duration::from_millis(10));
    }

    #[test]
    fn bench_measures_sleep() {
        let s = bench("sleep", 0, 3, || {
            std::thread::sleep(Duration::from_millis(2));
        });
        assert!(s.mean >= Duration::from_millis(2));
        assert!(s.min >= Duration::from_millis(2));
    }

    #[test]
    fn bench_for_runs_at_least_three() {
        let s = bench_for("fast", Duration::from_millis(1), || 1u8);
        assert!(s.iters >= 3);
        assert_eq!(s.times.len(), s.iters as usize);
    }

    #[test]
    fn sample_metric_from_raw_times() {
        let s = bench("noop", 0, 4, || 1u8);
        assert_eq!(s.times.len(), 4);
        let m = s.metric_ns("noop.ns", 0.5);
        assert_eq!(m.summary.n, 4);
        assert_eq!(m.direction, Direction::Lower);
        assert_eq!(m.samples.len(), 4);
        assert!((m.summary.mean - s.mean_ns() * 0.5).abs() <= s.mean_ns() * 0.5 * 1e-9);
    }
}
