//! The split-stack frame machine.

use crate::error::{Error, Result};
use crate::pmem::{BlockAlloc, BlockAllocator, BlockId};
use crate::stack::FrameRef;

/// Per-block header: link to the previous block and the stack offset to
/// restore when this block is released.
const HEADER_BYTES: usize = 16;

/// Split-stack statistics — the quantities Figure 3's model consumes.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StackStats {
    /// Function calls executed (each pays the ~3-instruction check).
    pub calls: u64,
    /// Calls that overflowed into a fresh block (paid the slow path).
    pub overflows: u64,
    /// Argument bytes copied across block boundaries on overflow.
    pub args_copied: u64,
    /// Blocks currently in the chain.
    pub blocks_live: usize,
    /// High-water mark of chained blocks.
    pub blocks_peak: usize,
}

struct FrameMeta {
    block: BlockId,
    /// Offset of the frame base within its block.
    base: usize,
    /// Frame payload size.
    size: usize,
    /// True if this frame opened a fresh block (return frees it).
    opened_block: bool,
}

/// A segmented program stack over fixed-size allocator blocks.
///
/// `call` = function prologue (space check, possible block switch, arg
/// copy); `ret` = epilogue (possible block release). Frame locals are
/// accessed through [`FrameRef`] with bounds checks.
pub struct SplitStack<'a, A: BlockAlloc = BlockAllocator> {
    alloc: &'a A,
    /// Current (top) block and bump offset within it.
    top: BlockId,
    sp: usize,
    frames: Vec<FrameMeta>,
    stats: StackStats,
}

impl<'a, A: BlockAlloc> SplitStack<'a, A> {
    /// Create a stack with one initial block.
    pub fn new(alloc: &'a A) -> Result<Self> {
        let top = alloc.alloc()?;
        Ok(SplitStack {
            alloc,
            top,
            sp: HEADER_BYTES,
            frames: Vec::new(),
            stats: StackStats {
                blocks_live: 1,
                blocks_peak: 1,
                ..Default::default()
            },
        })
    }

    /// Maximum frame payload a single block can hold.
    pub fn max_frame(&self) -> usize {
        self.alloc.block_size() - HEADER_BYTES
    }

    /// Function prologue: push a frame of `size` bytes, copying `args`
    /// into its base (the "non-register arguments").
    ///
    /// The fast path is the paper's 3-instruction check: compare
    /// `sp + size` against the block limit and bump. The slow path
    /// allocates a block, links it, and copies `args`.
    pub fn call(&mut self, size: usize, args: &[u8]) -> Result<FrameRef> {
        if size > self.max_frame() {
            return Err(Error::FrameTooLarge {
                frame: size,
                payload: self.max_frame(),
            });
        }
        debug_assert!(args.len() <= size);
        self.stats.calls += 1;
        let mut opened_block = false;
        if self.sp + size > self.alloc.block_size() {
            // Slow path: chain a new block.
            let fresh = self.alloc.alloc()?;
            let mut header = [0u8; HEADER_BYTES];
            header[..8].copy_from_slice(&(self.top.0 as u64).to_le_bytes());
            header[8..].copy_from_slice(&(self.sp as u64).to_le_bytes());
            self.alloc.write(fresh, 0, &header)?;
            self.top = fresh;
            self.sp = HEADER_BYTES;
            self.stats.overflows += 1;
            self.stats.args_copied += args.len() as u64;
            self.stats.blocks_live += 1;
            self.stats.blocks_peak = self.stats.blocks_peak.max(self.stats.blocks_live);
            opened_block = true;
        }
        let base = self.sp;
        if !args.is_empty() {
            self.alloc.write(self.top, base, args)?;
        }
        self.sp += size;
        self.frames.push(FrameMeta {
            block: self.top,
            base,
            size,
            opened_block,
        });
        Ok(FrameRef(self.frames.len() - 1))
    }

    /// Function epilogue: pop the top frame, releasing its block if the
    /// frame opened one.
    pub fn ret(&mut self) -> Result<()> {
        let f = self.frames.pop().ok_or(Error::StackUnderflow)?;
        debug_assert_eq!(f.block, self.top);
        if f.opened_block {
            // Restore the previous block from the header.
            let mut header = [0u8; HEADER_BYTES];
            self.alloc.read(self.top, 0, &mut header)?;
            let prev = BlockId(u64::from_le_bytes(header[..8].try_into().unwrap()) as u32);
            let prev_sp = u64::from_le_bytes(header[8..].try_into().unwrap()) as usize;
            self.alloc.free(self.top)?;
            self.top = prev;
            self.sp = prev_sp;
            self.stats.blocks_live -= 1;
        } else {
            self.sp = f.base;
        }
        Ok(())
    }

    /// Write into the top-most validity-checked frame's locals.
    pub fn write_local(&mut self, frame: FrameRef, offset: usize, data: &[u8]) -> Result<()> {
        let f = self.frame(frame)?;
        if offset + data.len() > f.size {
            return Err(Error::IndexOutOfBounds {
                index: offset + data.len(),
                len: f.size,
            });
        }
        self.alloc.write(f.block, f.base + offset, data)
    }

    /// Read from a live frame's locals.
    pub fn read_local(&self, frame: FrameRef, offset: usize, out: &mut [u8]) -> Result<()> {
        let f = self.frame(frame)?;
        if offset + out.len() > f.size {
            return Err(Error::IndexOutOfBounds {
                index: offset + out.len(),
                len: f.size,
            });
        }
        self.alloc.read(f.block, f.base + offset, out)
    }

    fn frame(&self, frame: FrameRef) -> Result<&FrameMeta> {
        self.frames.get(frame.0).ok_or(Error::StackUnderflow)
    }

    /// Current call depth.
    pub fn depth(&self) -> usize {
        self.frames.len()
    }

    /// Statistics snapshot.
    pub fn stats(&self) -> StackStats {
        self.stats
    }
}

impl<A: BlockAlloc> Drop for SplitStack<'_, A> {
    fn drop(&mut self) {
        // Unwind any live frames, then release the initial block.
        while self.ret().is_ok() {}
        let _ = self.alloc.free(self.top);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::forall;

    fn alloc() -> BlockAllocator {
        BlockAllocator::new(1024, 512).unwrap()
    }

    #[test]
    fn push_pop_single_frame() {
        let a = alloc();
        let mut s = SplitStack::new(&a).unwrap();
        let f = s.call(64, b"args").unwrap();
        let mut out = [0u8; 4];
        s.read_local(f, 0, &mut out).unwrap();
        assert_eq!(&out, b"args");
        s.ret().unwrap();
        assert_eq!(s.depth(), 0);
    }

    #[test]
    fn overflow_allocates_and_frees_blocks() {
        let a = alloc();
        let mut s = SplitStack::new(&a).unwrap();
        // 1008-byte payload per 1024-byte block; 300-byte frames: 3 per
        // block.
        for _ in 0..10 {
            s.call(300, &[]).unwrap();
        }
        assert!(s.stats().overflows > 0);
        let peak = s.stats().blocks_peak;
        assert!(peak >= 3, "peak {peak}");
        for _ in 0..10 {
            s.ret().unwrap();
        }
        assert_eq!(s.stats().blocks_live, 1);
        drop(s);
        assert_eq!(a.stats().allocated, 0);
    }

    #[test]
    fn args_survive_block_switch() {
        let a = alloc();
        let mut s = SplitStack::new(&a).unwrap();
        // Fill the first block almost exactly.
        s.call(900, &[]).unwrap();
        // Next call must overflow; its args must be intact in the new
        // block (the copy the paper describes).
        let args: Vec<u8> = (0..200u8).collect();
        let f = s.call(256, &args).unwrap();
        let mut out = vec![0u8; 200];
        s.read_local(f, 0, &mut out).unwrap();
        assert_eq!(out, args);
        assert_eq!(s.stats().overflows, 1);
        assert_eq!(s.stats().args_copied, 200);
    }

    #[test]
    fn frame_too_large_rejected() {
        let a = alloc();
        let mut s = SplitStack::new(&a).unwrap();
        assert!(matches!(
            s.call(2000, &[]),
            Err(Error::FrameTooLarge { .. })
        ));
    }

    #[test]
    fn underflow_rejected() {
        let a = alloc();
        let mut s = SplitStack::new(&a).unwrap();
        assert!(matches!(s.ret(), Err(Error::StackUnderflow)));
    }

    #[test]
    fn locals_bounds_checked() {
        let a = alloc();
        let mut s = SplitStack::new(&a).unwrap();
        let f = s.call(32, &[]).unwrap();
        assert!(s.write_local(f, 30, &[0u8; 4]).is_err());
        let mut buf = [0u8; 4];
        assert!(s.read_local(f, 30, &mut buf).is_err());
    }

    #[test]
    fn deep_recursion_many_blocks() {
        let a = BlockAllocator::new(1024, 512).unwrap();
        let mut s = SplitStack::new(&a).unwrap();
        let depth = 1000usize;
        for i in 0..depth {
            let f = s.call(128, &(i as u64).to_le_bytes()).unwrap();
            assert_eq!(f.depth(), i);
        }
        // Unwind verifying each frame's argument on the way down.
        for i in (0..depth).rev() {
            let f = FrameRef(i);
            let mut out = [0u8; 8];
            s.read_local(f, 0, &mut out).unwrap();
            assert_eq!(u64::from_le_bytes(out), i as u64);
            s.ret().unwrap();
        }
        assert_eq!(s.stats().blocks_live, 1);
    }

    #[test]
    fn prop_lifo_discipline_preserves_locals() {
        forall(30, |g| {
            let a = BlockAllocator::new(1024, 1024).unwrap();
            let mut s = SplitStack::new(&a).unwrap();
            let mut model: Vec<(usize, u64)> = Vec::new(); // (size, tag)
            for step in 0..g.usize_in(1, 300) {
                if g.bool(0.6) || model.is_empty() {
                    let size = g.usize_in(16, 800);
                    let tag = (step as u64) << 16 | size as u64;
                    let f = s.call(size, &tag.to_le_bytes()).unwrap();
                    assert_eq!(f.depth(), model.len());
                    model.push((size, tag));
                } else {
                    model.pop();
                    s.ret().unwrap();
                }
                // Every live frame's tag must still be readable.
                for (i, (_, tag)) in model.iter().enumerate() {
                    let mut out = [0u8; 8];
                    s.read_local(FrameRef(i), 0, &mut out).unwrap();
                    assert_eq!(u64::from_le_bytes(out), *tag, "frame {i}");
                }
            }
            assert_eq!(s.depth(), model.len());
        });
    }

    #[test]
    fn prop_block_conservation() {
        forall(20, |g| {
            let a = BlockAllocator::new(1024, 1024).unwrap();
            {
                let mut s = SplitStack::new(&a).unwrap();
                for _ in 0..g.usize_in(0, 500) {
                    if g.bool(0.55) {
                        let _ = s.call(g.usize_in(8, 900), &[]);
                    } else {
                        let _ = s.ret();
                    }
                    // blocks_live tracks reality.
                    assert_eq!(a.stats().allocated, s.stats().blocks_live);
                }
            }
            assert_eq!(a.stats().allocated, 0); // drop unwound everything
        });
    }
}
