//! Frame handles.

/// A handle to a live stack frame (index into the frame metadata stack).
///
/// Frames obey LIFO discipline: only the most recent frame may be
/// returned from, and handles to popped frames are rejected.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FrameRef(pub(crate) usize);

impl FrameRef {
    /// Depth of this frame (0 = first call).
    pub fn depth(self) -> usize {
        self.0
    }
}
