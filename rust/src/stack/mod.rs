//! Split stacks (paper §3.1).
//!
//! Without large contiguous regions the program stack becomes a chain of
//! fixed-size blocks. Every function call checks whether the current
//! block has room for its frame (≈3 x86 instructions); in the rare
//! overflow case a new block is allocated, non-register arguments are
//! copied over, and the stack pointer is adjusted — all undone at return.
//! This is gcc's `-fsplit-stack` with allocation requests pinned to the
//! OS block size, exactly the configuration the paper measured.
//!
//! * [`SplitStack`] — the executable frame machine over any
//!   [`crate::pmem::BlockAlloc`] pool (correctness + measured
//!   check cost).
//! * [`CallTrace`] / [`TraceRunner`] — synthetic call-tree generation
//!   and replay against both the split stack and a contiguous reference.
//! * [`profiles`] — the per-benchmark call-density model behind
//!   Figure 3.

mod call_trace;
mod frame;
pub mod profiles;
mod split_stack;

pub use call_trace::{CallEvent, CallTrace, TraceRunner};
pub use frame::FrameRef;
pub use profiles::{BenchmarkProfile, SPLIT_STACK_CHECK_INSNS};
pub use split_stack::{SplitStack, StackStats};
