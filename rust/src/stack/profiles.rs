//! Per-benchmark call profiles behind Figure 3.
//!
//! The paper compiled SPECInt2017 + PARSEC with gcc `-fsplit-stack` and
//! measured normalized runtime. The suites are licensed and the
//! measurement needs their testbed, so (per DESIGN.md's substitution
//! table) Figure 3 is reproduced from the quantity that actually drives
//! it: **dynamic call density**. Split stacks add ~3 instructions per
//! call ([`SPLIT_STACK_CHECK_INSNS`], the paper's number, validated at
//! runtime by the Fibonacci microbenchmark in `workloads::fib`), so
//!
//! ```text
//! runtime ratio ≈ 1 + check_insns · (calls / kilo-insn) / 1000 · ipc_scale
//! ```
//!
//! Call densities below are representative values from published
//! characterization studies of the suites (call-intensive: xalancbmk,
//! leela, ferret; loop-dominated: mcf, xz, streamcluster), chosen so the
//! *distribution* matches the paper's observation: average ≈ 2%, most
//! < 1%, none > 5% except the recursive microbenchmark at 15%.

/// Extra instructions per call for the split-stack space check (§3.1:
/// "about three x86 instructions").
pub const SPLIT_STACK_CHECK_INSNS: f64 = 3.0;

/// Which suite a profile belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Suite {
    /// SPECInt2017 (rate subset the paper kept).
    Spec2017,
    /// PARSEC 3.0.
    Parsec,
    /// The pessimistic recursive microbenchmark.
    Micro,
}

/// Dynamic profile of one benchmark.
#[derive(Clone, Copy, Debug)]
pub struct BenchmarkProfile {
    /// Benchmark name as in the paper's Figure 3.
    pub name: &'static str,
    /// Suite.
    pub suite: Suite,
    /// Dynamic calls per 1000 instructions.
    pub calls_per_kinsn: f64,
    /// Mean stack frame size in bytes (drives overflow frequency).
    pub mean_frame_bytes: usize,
    /// Recursion bias in [0,1] (drives max depth in generated traces).
    pub recursion_bias: f64,
    /// Relative efficiency of the check instructions vs the benchmark's
    /// average instruction (superscalar overlap makes cheap ALU checks
    /// cost < 1 average-instruction slot in wide loops, > in call chains).
    pub ipc_scale: f64,
}

impl BenchmarkProfile {
    /// Predicted split-stack runtime ratio (Figure 3's y-axis).
    ///
    /// `overflow_ratio` is the measured fraction of calls hitting the
    /// slow path (from a replayed trace); the slow path costs roughly
    /// `overflow_insns` instructions (allocation + arg copy + relink).
    pub fn predicted_ratio(&self, overflow_ratio: f64, overflow_insns: f64) -> f64 {
        let per_call = SPLIT_STACK_CHECK_INSNS + overflow_ratio * overflow_insns;
        1.0 + per_call * self.calls_per_kinsn / 1000.0 * self.ipc_scale
    }
}

/// The Figure 3 benchmark set: SPECInt2017 without exchange (FORTRAN)
/// and perlbench/gcc (crash under `-fsplit-stack`), all of PARSEC the
/// paper ran, and the Fibonacci microbenchmark.
pub const FIGURE3_PROFILES: &[BenchmarkProfile] = &[
    // SPECInt2017 — call densities from suite characterizations.
    p("mcf_r", Suite::Spec2017, 2.1, 96, 0.3, 0.9),
    p("omnetpp_r", Suite::Spec2017, 11.0, 144, 0.4, 1.0),
    p("xalancbmk_r", Suite::Spec2017, 14.5, 128, 0.5, 1.0),
    p("x264_r", Suite::Spec2017, 1.6, 256, 0.2, 0.8),
    p("deepsjeng_r", Suite::Spec2017, 6.8, 176, 0.8, 1.0),
    p("leela_r", Suite::Spec2017, 9.4, 160, 0.7, 1.0),
    p("xz_r", Suite::Spec2017, 0.7, 208, 0.2, 0.8),
    // PARSEC.
    p("blackscholes", Suite::Parsec, 0.4, 112, 0.1, 0.8),
    p("bodytrack", Suite::Parsec, 3.2, 192, 0.3, 0.9),
    p("canneal", Suite::Parsec, 2.4, 128, 0.3, 0.9),
    p("dedup", Suite::Parsec, 1.9, 240, 0.2, 0.9),
    p("facesim", Suite::Parsec, 2.8, 320, 0.3, 0.9),
    p("ferret", Suite::Parsec, 7.6, 224, 0.4, 1.0),
    p("fluidanimate", Suite::Parsec, 1.1, 96, 0.2, 0.8),
    p("freqmine", Suite::Parsec, 4.2, 160, 0.6, 1.0),
    p("raytrace", Suite::Parsec, 5.5, 144, 0.7, 1.0),
    p("streamcluster", Suite::Parsec, 0.5, 80, 0.1, 0.8),
    p("swaptions", Suite::Parsec, 2.2, 176, 0.3, 0.9),
    p("vips", Suite::Parsec, 3.9, 208, 0.3, 0.9),
    // The pessimistic case: recursive Fibonacci makes a call every ~20
    // instructions, amplifying the check cost to the paper's 15%.
    p("fib (micro)", Suite::Micro, 50.0, 48, 1.0, 1.0),
];

const fn p(
    name: &'static str,
    suite: Suite,
    calls_per_kinsn: f64,
    mean_frame_bytes: usize,
    recursion_bias: f64,
    ipc_scale: f64,
) -> BenchmarkProfile {
    BenchmarkProfile {
        name,
        suite,
        calls_per_kinsn,
        mean_frame_bytes,
        recursion_bias,
        ipc_scale,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fib_predicts_paper_fifteen_percent() {
        let fib = FIGURE3_PROFILES.last().unwrap();
        let r = fib.predicted_ratio(0.0, 0.0);
        assert!((1.10..=1.20).contains(&r), "fib ratio {r}");
    }

    #[test]
    fn standard_benchmarks_average_two_percent() {
        let std: Vec<_> = FIGURE3_PROFILES
            .iter()
            .filter(|b| b.suite != Suite::Micro)
            .collect();
        let mean: f64 =
            std.iter().map(|b| b.predicted_ratio(0.001, 40.0)).sum::<f64>() / std.len() as f64;
        assert!(
            (1.005..=1.035).contains(&mean),
            "mean overhead {mean} outside the paper's ~2%"
        );
    }

    #[test]
    fn most_benchmarks_under_one_percent_or_so() {
        let under: usize = FIGURE3_PROFILES
            .iter()
            .filter(|b| b.suite != Suite::Micro)
            .filter(|b| b.predicted_ratio(0.001, 40.0) < 1.02)
            .count();
        assert!(under >= 10, "only {under} benchmarks below 2%");
    }

    #[test]
    fn overflow_raises_ratio() {
        let b = &FIGURE3_PROFILES[0];
        assert!(b.predicted_ratio(0.05, 40.0) > b.predicted_ratio(0.0, 40.0));
    }
}
