//! Synthetic call traces: generation and replay.
//!
//! A trace is a balanced sequence of call/return events with frame sizes
//! drawn from a profile. Replaying it against [`SplitStack`] measures the
//! *real* per-call check cost; replaying against a plain contiguous
//! buffer gives the baseline. The Figure 3 bench uses both plus the
//! analytic model in [`crate::stack::profiles`].

use crate::error::Result;
use crate::pmem::BlockAlloc;
use crate::stack::{SplitStack, StackStats};
use crate::testutil::Rng;

/// One event in a call trace.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CallEvent {
    /// Push a frame of the given payload size with `args` argument bytes.
    Call {
        /// Frame payload bytes.
        size: u16,
        /// Argument bytes copied on call.
        args: u8,
    },
    /// Pop the top frame.
    Ret,
}

/// A balanced call/return sequence.
#[derive(Clone, Debug)]
pub struct CallTrace {
    /// Events in program order (calls ≥ rets at every prefix; balanced
    /// overall).
    pub events: Vec<CallEvent>,
    /// Maximum depth reached.
    pub max_depth: usize,
}

impl CallTrace {
    /// Generate a random trace of ~`n_calls` calls.
    ///
    /// `mean_frame` controls frame sizes (uniform in [mean/2, 3*mean/2],
    /// clamped to the stack's max); `recursion_bias` ∈ [0,1] skews toward
    /// deep chains (1.0 ≈ fib-like recursion, 0.0 ≈ flat call fan-out).
    pub fn generate(rng: &mut Rng, n_calls: usize, mean_frame: usize, recursion_bias: f64) -> Self {
        let mut events = Vec::with_capacity(2 * n_calls);
        let mut depth = 0usize;
        let mut max_depth = 0usize;
        let mut calls = 0usize;
        let lo = (mean_frame / 2).max(8);
        let hi = (mean_frame * 3 / 2).max(lo + 1);
        while calls < n_calls || depth > 0 {
            let push = calls < n_calls
                && (depth == 0 || {
                    // Deeper stacks keep pushing with prob ~ bias.
                    let p = 0.35 + 0.6 * recursion_bias;
                    rng.chance(p)
                });
            if push {
                let size = rng.range(lo, hi).min(u16::MAX as usize) as u16;
                let args = rng.range(0, 32.min(size as usize)) as u8;
                events.push(CallEvent::Call { size, args });
                depth += 1;
                calls += 1;
                max_depth = max_depth.max(depth);
            } else {
                events.push(CallEvent::Ret);
                depth -= 1;
            }
        }
        CallTrace { events, max_depth }
    }

    /// Number of call events.
    pub fn n_calls(&self) -> usize {
        self.events
            .iter()
            .filter(|e| matches!(e, CallEvent::Call { .. }))
            .count()
    }
}

/// Replays traces against split and contiguous stacks.
pub struct TraceRunner;

/// A dummy args buffer (contents don't matter for timing; size ≤ 32).
const ARGS: [u8; 32] = [0xA5; 32];

impl TraceRunner {
    /// Replay on a [`SplitStack`]; returns final stats.
    pub fn run_split<A: BlockAlloc>(trace: &CallTrace, alloc: &A) -> Result<StackStats> {
        let mut s = SplitStack::new(alloc)?;
        for ev in &trace.events {
            match *ev {
                CallEvent::Call { size, args } => {
                    s.call(size as usize, &ARGS[..args as usize])?;
                }
                CallEvent::Ret => s.ret()?,
            }
        }
        Ok(s.stats())
    }

    /// Replay on a contiguous stack (one big buffer, classic bump): the
    /// virtual-memory baseline. Returns bytes touched (to keep the work
    /// comparable and the optimizer honest).
    pub fn run_contiguous(trace: &CallTrace, buf: &mut Vec<u8>) -> u64 {
        let mut sp = 0usize;
        let mut bases: Vec<usize> = Vec::with_capacity(trace.max_depth);
        let mut touched = 0u64;
        for ev in &trace.events {
            match *ev {
                CallEvent::Call { size, args } => {
                    let size = size as usize;
                    if sp + size > buf.len() {
                        buf.resize((sp + size).next_power_of_two(), 0);
                    }
                    buf[sp..sp + args as usize].copy_from_slice(&ARGS[..args as usize]);
                    bases.push(sp);
                    sp += size;
                    touched += args as u64;
                }
                CallEvent::Ret => {
                    sp = bases.pop().expect("balanced trace");
                }
            }
        }
        touched
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::BlockAllocator;
    use crate::testutil::forall;

    #[test]
    fn generated_trace_is_balanced() {
        let mut rng = Rng::new(1);
        let t = CallTrace::generate(&mut rng, 500, 128, 0.5);
        let mut depth = 0i64;
        for ev in &t.events {
            match ev {
                CallEvent::Call { .. } => depth += 1,
                CallEvent::Ret => depth -= 1,
            }
            assert!(depth >= 0);
        }
        assert_eq!(depth, 0);
        assert_eq!(t.n_calls(), 500);
    }

    #[test]
    fn recursion_bias_deepens() {
        let mut rng = Rng::new(2);
        let flat = CallTrace::generate(&mut rng, 2000, 64, 0.0);
        let deep = CallTrace::generate(&mut rng, 2000, 64, 1.0);
        assert!(
            deep.max_depth > flat.max_depth * 2,
            "deep {} vs flat {}",
            deep.max_depth,
            flat.max_depth
        );
    }

    #[test]
    fn split_replay_matches_call_count() {
        let a = BlockAllocator::new(4096, 4096).unwrap();
        let mut rng = Rng::new(3);
        let t = CallTrace::generate(&mut rng, 1000, 200, 0.7);
        let stats = TraceRunner::run_split(&t, &a).unwrap();
        assert_eq!(stats.calls, 1000);
        assert_eq!(a.stats().allocated, 0); // stack dropped clean
    }

    #[test]
    fn contiguous_replay_runs() {
        let mut rng = Rng::new(4);
        let t = CallTrace::generate(&mut rng, 1000, 200, 0.7);
        let mut buf = Vec::new();
        TraceRunner::run_contiguous(&t, &mut buf);
        assert!(buf.len() >= 200);
    }

    #[test]
    fn prop_replay_never_leaks_blocks() {
        forall(15, |g| {
            let a = BlockAllocator::new(1024, 1 << 14).unwrap();
            let n = g.usize_in(1, 2000);
            let frame = g.usize_in(16, 400);
            let bias = g.rng().f64();
            let t = CallTrace::generate(g.rng(), n, frame, bias);
            TraceRunner::run_split(&t, &a).unwrap();
            assert_eq!(a.stats().allocated, 0);
        });
    }
}
