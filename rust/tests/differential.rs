//! The differential oracle suite — the repo's first property-style
//! integration tier: seeded random op sequences (scalar/batch get+set,
//! seqlock writer ops, view reads, safe + concurrent migration, swap
//! evict/restore, view/writer software page faults on evicted leaves
//! served through a retrying fault queue, injected swap I/O faults,
//! injected allocator OOM on migrate/restore/fault-in destinations)
//! run against a `Vec<u64>` mirror in lockstep, under BOTH allocator
//! policies. The op model
//! lives in `nvm::testutil::diffops` so unit suites and future
//! structures share it; failures shrink via `proptest_lite` (rerun
//! with `NVM_PROPTEST_SEED=<base>` to reproduce a reported case).
//!
//! CI runs this in `--release` as well: the case count is sized for
//! debug builds, and release speed buys a denser op mix for free.

use std::sync::atomic::{AtomicU64, Ordering};

use nvm::pmem::{BlockAllocator, ShardedAllocator};
use nvm::testutil::{diffops, forall};

/// 1 KB blocks keep trees multi-leaf at tiny sizes (u64 leaf_cap 128).
const BLOCK: usize = 1024;
const CASES: u32 = 40;

/// Run `CASES` differential cases against a fresh pool per case,
/// accumulating outcome counters so the suite can prove the generator
/// actually exercised every op family (a weight bug that starves, say,
/// eviction would otherwise pass vacuously).
fn run_suite<F>(mk_case: F)
where
    F: Fn(&mut nvm::testutil::Gen) -> diffops::DiffOutcome + std::panic::RefUnwindSafe,
{
    let ops = AtomicU64::new(0);
    let writer_writes = AtomicU64::new(0);
    let migrations = AtomicU64::new(0);
    let evictions = AtomicU64::new(0);
    let restores = AtomicU64::new(0);
    let hook_faults = AtomicU64::new(0);
    let injected_oom = AtomicU64::new(0);
    forall(CASES, |g| {
        let o = mk_case(g);
        ops.fetch_add(o.ops as u64, Ordering::Relaxed);
        writer_writes.fetch_add(o.writer_writes as u64, Ordering::Relaxed);
        migrations.fetch_add(o.migrations as u64, Ordering::Relaxed);
        evictions.fetch_add(o.evictions as u64, Ordering::Relaxed);
        restores.fetch_add(o.restores as u64, Ordering::Relaxed);
        hook_faults.fetch_add(o.hook_faults as u64, Ordering::Relaxed);
        injected_oom.fetch_add(o.injected_oom as u64, Ordering::Relaxed);
    });
    assert!(ops.load(Ordering::Relaxed) > 0);
    assert!(
        writer_writes.load(Ordering::Relaxed) > 0,
        "no case exercised the seqlock writer"
    );
    assert!(migrations.load(Ordering::Relaxed) > 0, "no case migrated a leaf");
    assert!(evictions.load(Ordering::Relaxed) > 0, "no case evicted a leaf");
    assert!(
        hook_faults.load(Ordering::Relaxed) > 0,
        "no case took a software page fault through an accessor"
    );
    assert!(
        injected_oom.load(Ordering::Relaxed) > 0,
        "no case injected an allocator OOM"
    );
    assert_eq!(
        evictions.load(Ordering::Relaxed),
        restores.load(Ordering::Relaxed) + hook_faults.load(Ordering::Relaxed),
        "every successful eviction must come back exactly once \
         (daemon-style restore or accessor demand fault)"
    );
}

#[test]
fn differential_mutex_allocator() {
    run_suite(|g| {
        let a = BlockAllocator::new(BLOCK, 1 << 12).unwrap();
        diffops::run_case(&a, g)
    });
}

#[test]
fn differential_sharded_allocator() {
    run_suite(|g| {
        let a = ShardedAllocator::with_shards(BLOCK, 1 << 12, 4).unwrap();
        diffops::run_case(&a, g)
    });
}

#[test]
fn differential_reuses_one_pool_across_cases() {
    // The pool-reuse shape: stale state (recycled blocks, epoch/limbo
    // counters, scribbled contents) from one case must never leak into
    // the next — each case asserts it returns the pool to empty.
    let a = BlockAllocator::new(BLOCK, 1 << 12).unwrap();
    forall(20, |g| {
        diffops::run_case(&a, g);
    });
}
