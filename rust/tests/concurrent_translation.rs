//! Stress tests for the concurrent read-side translation subsystem:
//! reader threads with per-thread leaf-TLB views verify checksums while
//! a migrator thread relocates leaves out from under them with
//! [`TreeArray::migrate_leaf_concurrent`] and recycles the displaced
//! blocks through the arena epoch — under both allocator policies.
//!
//! The hazard being stressed is the concurrent cousin of
//! `tests/translation.rs`'s scenario: a view holds a cached leaf
//! translation, the leaf migrates, the displaced block is freed,
//! recycled to a new owner, and scribbled — all while reads are in
//! flight. The epoch protocol must make the scribble unobservable: the
//! block may not leave limbo until every registered reader has pinned
//! past the move, and a reader pinning past the move flushes its TLB
//! before dereferencing anything. Any stale read shows up as a checksum
//! mismatch against immutable reference data.
//!
//! Run in `--release` too (CI does): the interesting interleavings
//! rarely open up at debug-build speeds.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

use nvm::pmem::{BlockAlloc, BlockAllocator, ShardedAllocator};
use nvm::testutil::Rng;
use nvm::trees::TreeArray;

const BLOCK: usize = 1024; // u64: leaf_cap 128, fanout 128

/// One thread relocates + recycles + scribbles; `readers` threads read
/// through per-thread TLB views and compare every value against the
/// reference. Exercises single reads and batch reads.
fn shootdown_stress<A: BlockAlloc>(a: &A, readers: usize, migrations: usize) {
    let n = 128 * 24 + 17; // 25 leaves, partial tail
    let mut tree: TreeArray<u64, A> = TreeArray::new(a, n).unwrap();
    let data: Vec<u64> = (0..n as u64)
        .map(|i| i.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1)
        .collect();
    tree.copy_from_slice(&data).unwrap();
    tree.enable_flat_table();
    let _ = tree.get(0); // build the flat table before sharing
    let live_before = a.stats().allocated;

    let tree = &tree;
    let data = &data;
    let stop = AtomicBool::new(false);
    let stop = &stop;
    let total_invalidations = AtomicU64::new(0);
    let total_invalidations = &total_invalidations;

    std::thread::scope(|s| {
        for tid in 0..readers {
            s.spawn(move || {
                let mut view = tree.view();
                let mut rng = Rng::new(0xABCD + tid as u64);
                let mut idxs = vec![0usize; 64];
                while !stop.load(Ordering::Relaxed) {
                    // Point reads.
                    for _ in 0..256 {
                        let i = rng.range(0, n);
                        // SAFETY: i < n.
                        let v = unsafe { view.get_unchecked(i) };
                        assert_eq!(v, data[i], "stale read of element {i} through a view TLB");
                    }
                    // Batch reads (one pin, grouped translation).
                    for slot in idxs.iter_mut() {
                        *slot = rng.range(0, n);
                    }
                    let got = view.get_batch(&idxs).unwrap();
                    for (k, &i) in idxs.iter().enumerate() {
                        assert_eq!(got[k], data[i], "stale batch read of element {i}");
                    }
                }
                total_invalidations.fetch_add(view.tlb_stats().invalidations, Ordering::Relaxed);
            });
        }

        // Migrator: relocate, reclaim, and recycle-and-scribble — the
        // pattern from tests/translation.rs, now against live readers.
        let mut rng = Rng::new(0x517E);
        let mut done = 0usize;
        while done < migrations {
            let leaf = rng.range(0, tree.nleaves());
            // SAFETY: concurrent access is only through epoch-registered
            // views; no raw leaf slices; this is the only migrator.
            if unsafe { tree.migrate_leaf_concurrent(leaf) }.is_err() {
                // Pool pressure: limbo holds the free blocks until the
                // readers quiesce. Reclaim and give them a timeslice.
                a.epoch().try_reclaim(a);
                std::thread::yield_now();
                continue;
            }
            done += 1;
            // Return quiesced blocks to the pool, then grab a block and
            // scribble it: under a LIFO free list this is frequently the
            // just-reclaimed block — exactly the recycled memory a stale
            // TLB entry would be pointing at.
            a.epoch().try_reclaim(a);
            if let Ok(b) = a.alloc() {
                a.write(b, 0, &[0xA5u8; BLOCK]).unwrap();
                a.free(b).unwrap();
            }
            if done % 16 == 0 {
                std::thread::yield_now();
            }
        }
        stop.store(true, Ordering::Relaxed);
    });

    // Views are gone: limbo must drain fully and nothing may leak.
    a.epoch().synchronize(a);
    assert_eq!(a.epoch().limbo_len(), 0);
    assert_eq!(
        a.stats().allocated,
        live_before,
        "relocation churn leaked or double-freed blocks"
    );
    assert_eq!(tree.to_vec(), data, "tree contents corrupted by the churn");
    assert!(
        total_invalidations.load(Ordering::Relaxed) > 0,
        "readers never observed a shootdown — the stress ran vacuously"
    );
}

#[test]
fn epoch_shootdown_stress_mutex_allocator() {
    let a = BlockAllocator::new(BLOCK, 256).unwrap();
    shootdown_stress(&a, 3, 400);
}

#[test]
fn epoch_shootdown_stress_sharded_allocator() {
    let a = ShardedAllocator::with_shards(BLOCK, 256, 4).unwrap();
    shootdown_stress(&a, 3, 400);
}

/// The deterministic core of the protocol, step by step (no timing
/// dependence): a view's cached translation pins the displaced block in
/// limbo; recycling cannot happen until the view quiesces; the view's
/// next access flushes and re-translates.
fn deterministic_quiescence<A: BlockAlloc>(a: &A) {
    let n = 128 * 4;
    let mut tree: TreeArray<u64, A> = TreeArray::new(a, n).unwrap();
    let data: Vec<u64> = (0..n as u64).map(|i| i ^ 0xFACE).collect();
    tree.copy_from_slice(&data).unwrap();

    let mut view = tree.view();
    assert_eq!(view.get(5).unwrap(), data[5]); // leaf 0 cached + pinned
    // SAFETY: the only other accessor is the epoch-registered view.
    unsafe { tree.migrate_leaf_concurrent(0) }.unwrap();
    // The displaced block must NOT be reusable yet: the view could
    // still be mid-read at its old pin.
    assert_eq!(a.epoch().try_reclaim(a), 0);
    assert_eq!(a.epoch().limbo_len(), 1);
    // Next read pins the new epoch, flushes, re-translates — correct
    // value, and the old block becomes reclaimable.
    assert_eq!(view.get(5).unwrap(), data[5]);
    assert!(view.tlb_stats().invalidations >= 1, "flush must be counted");
    assert_eq!(a.epoch().try_reclaim(a), 1);
    // Recycle-and-scribble now; the view must be unaffected.
    let b = a.alloc().unwrap();
    a.write(b, 0, &[0x5Au8; BLOCK]).unwrap();
    assert_eq!(view.get(5).unwrap(), data[5]);
    assert_eq!(view.get(200).unwrap(), data[200]);
    a.free(b).unwrap();
}

#[test]
fn deterministic_quiescence_mutex_allocator() {
    let a = BlockAllocator::new(BLOCK, 64).unwrap();
    deterministic_quiescence(&a);
}

#[test]
fn deterministic_quiescence_sharded_allocator() {
    let a = ShardedAllocator::with_shards(BLOCK, 64, 2).unwrap();
    deterministic_quiescence(&a);
}
