//! pallas-kv differential oracle: the store vs a plain `BTreeMap`
//! mirror, under mmd churn and injected transient swap faults.
//!
//! One deterministic op thread drives put/get/delete/range against a
//! [`KvStore`] and a `BTreeMap<key, (value, rev)>` mirror side by side,
//! comparing every result exactly — while the mmd daemon evicts and
//! restores the leaves underneath, a chaos reader hammers the same
//! keyspace through its own handler, and an injector arms single-shot
//! transient swap faults (always within the retry budget) plus
//! completion-ordering delays. Because the op thread is the only
//! writer, the store's visible state is a pure function of the op
//! sequence — any divergence from the mirror is a bug in the cell
//! protocol, the fault path, or eviction, not test noise.
//!
//! The watch ring is sized to hold the whole history, so replaying it
//! from sequence 0 must reconstruct exactly the mirror's final keyset
//! and revisions.
//!
//! Runs against both allocator policies. Seeds come from a fixed base
//! (override with `NVM_PROPTEST_SEED=<n>` to reproduce a reported
//! case).
//!
//! [`KvStore`]: nvm::kv::KvStore

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

use nvm::kv::loadgen::{self, KeyDist, LoadgenConfig, MixConfig};
use nvm::kv::{EventKind, KvServer, KvStore, Request, Response, Transport};
use nvm::mmd::{MmdConfig, MmdHandle, ThresholdPolicy};
use nvm::pmem::{BlockAlloc, BlockAllocator, FaultQueue, FaultQueueConfig, ShardedAllocator, SwapPool};
use nvm::testutil::{FailingBacking, Rng};
use nvm::trees::{CompactTarget, TreeArray, TreeRegistry};

/// 1 KB blocks keep trees multi-leaf at test sizes (u64 leaf_cap 128).
const BLOCK: usize = 1024;
/// 8 cells per 128-word leaf; 112-byte max value.
const CELL_WORDS: usize = 16;
/// 24 leaves + root = 25 tree blocks, 192 cells.
const LEAVES: usize = 24;
/// Pool budget: tree 25 + scratch 18 = 43 > 40, so with churn active
/// at least 3 leaves stay parked in swap at all times.
const CAP: usize = 40;
const PARKED: usize = 8;
const SCRATCH: usize = 18;
/// Key universe — half the cell count, so the freelist never empties
/// even with an in-flight out-of-place put per handler.
const NKEYS: u64 = 96;
const OPS: usize = 4_000;

fn base_seed() -> u64 {
    std::env::var("NVM_PROPTEST_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0x4B56) // "KV"
}

/// The differential run: deterministic ops vs the mirror under churn
/// and transient swap-fault injection.
fn run_case<A: BlockAlloc + Sync>(alloc: &A, seed: u64) {
    let tree = TreeArray::<u64, _>::new(alloc, LEAVES * (BLOCK / 8)).expect("kv diff tree");
    let registry = TreeRegistry::new();
    let (backing, ctl) = FailingBacking::new();
    let swap = SwapPool::with_backing(alloc, backing);
    let q = FaultQueue::new(
        &swap,
        FaultQueueConfig {
            backoff_base: Duration::from_micros(50),
            backoff_cap: Duration::from_millis(2),
            ..FaultQueueConfig::default()
        },
    );
    // SAFETY: cleared below before `q` drops.
    unsafe { tree.install_faulter(&q) };
    // SAFETY: all accessors are fault-capable store handlers.
    let reg_id = unsafe { registry.register_evictable(&tree) };

    // Ring cap covers every put/delete the run can emit, so the final
    // watch replay sees the complete history.
    let store = unsafe { KvStore::new(&tree, CELL_WORDS, 2 * OPS) }.expect("kv diff store");
    let mut mirror: BTreeMap<u64, (Vec<u8>, u64)> = BTreeMap::new();

    // Prefill half the keyspace before parking, so reads fault from
    // the very first op.
    {
        let mut h = store.handler();
        let mut rng = Rng::new(seed ^ 0xF111);
        for key in (0..NKEYS).step_by(2) {
            let val = loadgen::value_for(rng.next_u64(), 48);
            let rev = h.put(&loadgen::key_bytes(key), &val).expect("prefill put");
            mirror.insert(key, (val, rev));
        }
    }
    for leaf in 0..PARKED {
        // SAFETY: the register_evictable contract holds.
        unsafe { CompactTarget::evict_leaf(&tree, leaf, q.service()) }.expect("park leaf");
    }
    alloc.epoch().synchronize(alloc);
    let scratch = alloc.alloc_many(SCRATCH).expect("resident-budget scratch");

    let stop = AtomicBool::new(false);
    let st = std::thread::scope(|s| {
        let (store_r, stop_r, ctl_r) = (&store, &stop, &ctl);
        q.attach_workers(s, 2);
        let daemon = MmdHandle::spawn_with_swap(
            s,
            alloc,
            &registry,
            ThresholdPolicy::default(),
            MmdConfig {
                interval: Duration::from_micros(200),
                tokens_per_tick: 16,
                ..MmdConfig::default()
            },
            &q,
        );
        // Chaos reader: non-asserting traffic through its own handler
        // and translation caches — it must never observe an error or a
        // panic, but its results are unordered relative to the op
        // thread, so values are not compared.
        let chaos = s.spawn(move || {
            let mut h = store_r.handler();
            let mut rng = Rng::new(seed ^ 0xC4A0);
            let mut reads = 0u64;
            while !stop_r.load(Ordering::Relaxed) {
                let key = rng.below(NKEYS);
                if rng.chance(0.85) {
                    h.get(&loadgen::key_bytes(key)).expect("chaos get errored");
                } else {
                    h.range(&loadgen::key_bytes(key), &[], 5).expect("chaos range errored");
                }
                reads += 1;
            }
            reads
        });
        // Transient-fault injector, always within the retry budget.
        let injector = s.spawn(move || {
            let mut rng = Rng::new(seed ^ 0xF1A7);
            while !stop_r.load(Ordering::Relaxed) {
                ctl_r.fail_nth(1 + rng.below(4));
                if rng.chance(0.25) {
                    ctl_r.delay_nth(1 + rng.below(3), Duration::from_micros(200));
                }
                std::thread::sleep(Duration::from_millis(2));
            }
            ctl_r.disarm();
        });

        // The deterministic op thread: this scope's main thread.
        let mut h = store.handler();
        let mut rng = Rng::new(seed);
        for opno in 0..OPS {
            let key = rng.below(NKEYS);
            let kb = loadgen::key_bytes(key);
            match rng.below(100) {
                // 45% put
                0..=44 => {
                    let vlen = rng.below((store.max_value_len() + 1) as u64) as usize;
                    let mut val = vec![0u8; vlen];
                    for b in &mut val {
                        *b = rng.next_u64() as u8;
                    }
                    let rev = h.put(&kb, &val).expect("put failed");
                    if let Some((_, old_rev)) = mirror.get(&key) {
                        assert!(rev > *old_rev, "op {opno}: rev must advance");
                    }
                    mirror.insert(key, (val, rev));
                }
                // 35% get
                45..=79 => {
                    let got = h.get(&kb).expect("get failed");
                    let want = mirror.get(&key).map(|(v, r)| (v.clone(), *r));
                    assert_eq!(got, want, "op {opno}: get({key}) diverged from mirror");
                }
                // 10% delete
                80..=89 => {
                    let got = h.delete(&kb).expect("delete failed");
                    let want = mirror.remove(&key).map(|(_, r)| r);
                    assert_eq!(got, want, "op {opno}: delete({key}) diverged from mirror");
                }
                // 10% bounded range
                _ => {
                    let span = 1 + rng.below(16);
                    let limit = rng.below(8) as usize;
                    let end = loadgen::key_bytes(key.saturating_add(span));
                    let got = h.range(&kb, &end, limit).expect("range failed");
                    let want: Vec<(Vec<u8>, Vec<u8>, u64)> = mirror
                        .range(key..key.saturating_add(span))
                        .take(if limit == 0 { usize::MAX } else { limit })
                        .map(|(k, (v, r))| (loadgen::key_bytes(*k).to_vec(), v.clone(), *r))
                        .collect();
                    assert_eq!(got, want, "op {opno}: range({key}, +{span}) diverged");
                }
            }
        }
        stop.store(true, Ordering::Relaxed);
        let chaos_reads = chaos.join().unwrap();
        assert!(chaos_reads > 0, "chaos reader never ran");
        injector.join().unwrap();

        // Snapshot before shutdown: demand stays accessor-only.
        let st = q.stats();
        for b in scratch {
            alloc.free(b).expect("free scratch");
        }
        daemon.shutdown();
        q.shutdown_workers();
        st
    });

    assert_eq!(st.permanent, 0, "transient-only injection must never escalate: {st:?}");
    assert!(!q.degraded(), "backing is healthy by the end of the run");
    assert_eq!(registry.swapped_out(), 0, "shutdown must restore every parked leaf");
    assert!(st.demand > 0, "a churn differential run must take demand faults");

    // Final full-range sweep must equal the mirror exactly.
    {
        let mut h = store.handler();
        let got = h.range(&[], &[], 0).expect("final range");
        let want: Vec<(Vec<u8>, Vec<u8>, u64)> = mirror
            .iter()
            .map(|(k, (v, r))| (loadgen::key_bytes(*k).to_vec(), v.clone(), *r))
            .collect();
        assert_eq!(got, want, "final keyspace diverged from mirror");
    }
    // Watch replay from sequence 0 must reconstruct the final keyset
    // and revisions (the ring held the whole history).
    {
        let batch = store.watch(0, usize::MAX);
        assert_eq!(batch.first_seq_available, 0, "ring dropped history");
        let mut replay: BTreeMap<Vec<u8>, u64> = BTreeMap::new();
        for e in &batch.events {
            match e.kind {
                EventKind::Put => {
                    replay.insert(e.key.clone(), e.rev);
                }
                EventKind::Delete => {
                    replay.remove(&e.key);
                }
            }
        }
        let want: BTreeMap<Vec<u8>, u64> = mirror
            .iter()
            .map(|(k, (_, r))| (loadgen::key_bytes(*k).to_vec(), *r))
            .collect();
        assert_eq!(replay, want, "watch replay diverged from mirror");
    }

    drop(store);
    registry.deregister(reg_id);
    drop(registry);
    tree.clear_faulter();
    alloc.epoch().synchronize(alloc);
    drop(tree);
    drop(swap);
    assert_eq!(alloc.stats().allocated, 0, "kv differential leaked blocks");
}

#[test]
fn kv_differential_mutex_allocator() {
    let alloc = BlockAllocator::new(BLOCK, CAP).unwrap();
    run_case(&alloc, base_seed());
}

#[test]
fn kv_differential_sharded_allocator() {
    let alloc = ShardedAllocator::with_shards(BLOCK, CAP, 2).unwrap();
    run_case(&alloc, base_seed() ^ 0x5AD);
}

/// Replaying the same loadgen schedule against two fresh stores must
/// produce byte-identical final keyspaces (values *and* revisions):
/// the generator, the transport, and the put path are all
/// deterministic when there is a single client and a single worker.
#[test]
fn loadgen_replay_is_deterministic() {
    fn serve_once(seed: u64) -> Vec<(Vec<u8>, Vec<u8>, u64)> {
        let alloc = BlockAllocator::new(BLOCK, 64).unwrap();
        let tree = TreeArray::<u64, _>::new(&alloc, LEAVES * (BLOCK / 8)).unwrap();
        let store = unsafe { KvStore::new(&tree, CELL_WORDS, 2 * OPS) }.unwrap();
        let cfg = LoadgenConfig {
            ops: 2_000,
            rate: 0.0,
            nkeys: NKEYS,
            val_len: 64,
            scan_len: 4,
            dist: KeyDist::Zipfian(0.9),
            mix: MixConfig { name: "det", get_w: 40, put_w: 50, scan_w: 10 },
            seed,
            prefilled: false,
        };
        let server = KvServer::new();
        let entries = std::thread::scope(|s| {
            let worker = server.worker();
            let store_r = &store;
            let wh = s.spawn(move || {
                let mut h = store_r.handler();
                worker.run(&mut h)
            });
            let out = loadgen::run(&cfg, vec![server.connect()]);
            assert_eq!(out.errors, 0);
            assert_eq!(out.verify_failures, 0);
            let mut t = server.connect();
            let entries = match t.call(Request::Range { start: vec![], end: vec![], limit: 0 }) {
                Response::Entries { entries } => entries,
                other => panic!("unexpected response {other:?}"),
            };
            drop(t);
            drop(server);
            wh.join().unwrap();
            entries
        });
        drop(store);
        drop(tree);
        assert_eq!(alloc.stats().allocated, 0);
        entries
    }

    let a = serve_once(7);
    let b = serve_once(7);
    assert_eq!(a, b, "same seed must replay to an identical keyspace");
    assert!(!a.is_empty(), "schedule with 50% puts left the store empty");
    let c = serve_once(8);
    assert_ne!(a, c, "different seeds should diverge");
}
