//! Integration: the full telemetry pipeline — experiment run →
//! results record → JSON file → report rendering → regression diff —
//! plus cross-checks between the streaming and batch stat engines.
//!
//! Only `end_to_end_pipeline` touches the global sink (tests in one
//! binary run concurrently; the sink is process-global, so exactly one
//! test here may use it).

use std::path::PathBuf;

use nvm::coordinator::experiments::ExpConfig;
use nvm::coordinator::runner::run_experiment_recorded;
use nvm::telemetry::diff::DiffReport;
use nvm::telemetry::report::{render_dat, render_results};
use nvm::telemetry::{
    summarize, Direction, Json, LogHistogram, MetricRecord, Record, ResultsFile, ResultsWriter,
    Running, SCHEMA_VERSION,
};

fn tmp_path(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("nvm-telemetry-test-{}-{name}", std::process::id()));
    p
}

#[test]
fn end_to_end_pipeline() {
    // Run a real (quick) experiment through the recorded path.
    let cfg = ExpConfig {
        sample: 20_000,
        threads: 2,
        ..ExpConfig::default()
    };
    let (tables, records) = run_experiment_recorded("table2", &cfg).unwrap();
    assert!(!tables.is_empty());
    assert_eq!(records.len(), 1);
    assert!(!records[0].metrics.is_empty(), "table cells must flatten into metrics");

    // Write it, read it back: the round trip must be lossless.
    let mut w = ResultsWriter::new("itest");
    for r in records {
        w.add(r);
    }
    let path = tmp_path("roundtrip.json");
    let saved = w.save(&path).unwrap();
    let loaded = ResultsFile::load(&path).unwrap();
    assert_eq!(saved, loaded);
    assert_eq!(loaded.schema_version, SCHEMA_VERSION);
    assert_eq!(loaded.label, "itest");

    // Both renderers accept the file.
    let table = render_results(&loaded);
    assert!(table.contains("table2"));
    let dat = render_dat(&loaded);
    assert!(dat.contains("table2"));

    // A file diffed against itself reports nothing.
    let d = DiffReport::compare(&saved, &loaded);
    assert_eq!(d.regressions(), 0, "self-diff found regressions:\n{d}");
    assert_eq!(d.improvements(), 0);

    // Table cells flatten as Info metrics, which never fail a diff;
    // plant one directed metric on both sides and worsen the new copy
    // 10x — diff must flag exactly that regression.
    let mut base = loaded.clone();
    base.records[0].metrics.push(MetricRecord::from_value(
        "synthetic.latency",
        "us",
        Direction::Lower,
        10.0,
    ));
    let mut worse = base.clone();
    {
        let m = worse.records[0].metrics.last_mut().unwrap();
        m.summary.mean *= 10.0;
        for s in &mut m.samples {
            *s *= 10.0;
        }
    }
    assert_eq!(DiffReport::compare(&base, &base).regressions(), 0);
    let d = DiffReport::compare(&base, &worse);
    assert_eq!(d.regressions(), 1, "10x-worse metric not flagged:\n{d}");

    std::fs::remove_file(&path).ok();
}

#[test]
fn schema_violations_hard_fail() {
    let good = ResultsFile {
        schema_version: SCHEMA_VERSION,
        commit: "deadbeef".into(),
        label: "x".into(),
        records: vec![Record::new("r", "bench")],
    };
    assert!(ResultsFile::from_json(&good.to_json()).is_ok());

    // Wrong version.
    let mut wrong = good.clone();
    wrong.schema_version = SCHEMA_VERSION + 999;
    assert!(ResultsFile::from_json(&wrong.to_json()).is_err());

    // Missing commit key.
    let text = good.to_json().render().replace("\"commit\"", "\"commitx\"");
    let json = Json::parse(&text).unwrap();
    assert!(ResultsFile::from_json(&json).is_err());

    // Junk on disk.
    let path = tmp_path("junk.json");
    std::fs::write(&path, "{ not json").unwrap();
    assert!(ResultsFile::load(&path).is_err());
    std::fs::remove_file(&path).ok();
}

#[test]
fn verdict_flip_is_a_regression() {
    let mut old = ResultsFile {
        schema_version: SCHEMA_VERSION,
        commit: "c".into(),
        label: "old".into(),
        records: vec![Record::new("b", "bench")],
    };
    let mut new = old.clone();
    new.label = "new".into();
    old.records[0].verdict("gate", true, "ok");
    new.records[0].verdict("gate", false, "broke");
    let d = DiffReport::compare(&old, &new);
    assert_eq!(d.regressions(), 1);
    assert!(d.verdicts[0].regressed());
}

#[test]
fn running_matches_batch_summary() {
    // Streaming moments must agree with the batch path on the same data.
    let xs: Vec<f64> = (0..1000).map(|i| ((i * 37) % 101) as f64 * 0.5 + 3.0).collect();
    let mut r = Running::new();
    for &x in &xs {
        r.push(x);
    }
    let s = summarize(&xs);
    assert_eq!(r.count(), s.n);
    assert!((r.mean() - s.mean).abs() < 1e-9);
    assert!((r.stddev() - s.stddev).abs() < 1e-9);
    assert_eq!(r.min(), s.min);
    assert_eq!(r.max(), s.max);
}

#[test]
fn histogram_percentiles_bound_batch_percentiles() {
    // Log-bucket percentiles are bucket lower bounds: never above the
    // exact order statistic, within one sub-bucket (6.25%) below it,
    // and monotone in p.
    let mut h = LogHistogram::new();
    let vals: Vec<u64> = (1..=10_000u64).map(|i| (i * i) % 65_536 + 1).collect();
    for &v in &vals {
        h.record(v);
    }
    assert_eq!(h.count(), vals.len() as u64);
    let mut sorted = vals.clone();
    sorted.sort_unstable();
    let mut last = 0;
    for &(p, idx) in &[(0.50, 4_999usize), (0.99, 9_899), (0.999, 9_989)] {
        let est = h.percentile(p);
        let exact = sorted[idx];
        assert!(est <= exact, "p{p}: bucket lower bound {est} above exact {exact}");
        assert!(
            (exact - est) as f64 <= exact as f64 * 0.0625 + 1.0,
            "p{p}: estimate {est} too far below exact {exact}"
        );
        assert!(est >= last, "percentiles must be monotone");
        last = est;
    }
}

#[test]
fn merge_rejects_duplicate_records() {
    let part = ResultsFile {
        schema_version: SCHEMA_VERSION,
        commit: "c".into(),
        label: "p".into(),
        records: vec![Record::new("same", "bench")],
    };
    assert!(ResultsFile::merge("out", &[part.clone()]).is_ok());
    assert!(ResultsFile::merge("out", &[part.clone(), part]).is_err());
}
