//! Software page-fault stress: the `larger-than-DRAM` regime driven to
//! its edges. Live readers and writers run over a tree whose leaves the
//! mmd daemon keeps evicting under real pool pressure, every miss is
//! served by a worker-backed [`FaultQueue`] over a fault-injected swap
//! backing, and an injector thread arms transient I/O failures and
//! completion-ordering delays the whole time. The contract under test
//! (ISSUE acceptance): transient faults are retried with backoff and
//! never observed by accessors; permanent faults surface as typed
//! [`Error::SwapFaultFailed`] plus a degraded flag — never a panic, a
//! wedge, or data loss. Since PR 8 the degraded flag is scoped per
//! tenant: one tenant's dead backing must not park, degrade, or slow
//! any other tenant, and a recovery mid-drain restores each leaf
//! exactly once.
//!
//! CI runs this in `--release` as well; the deadline-bounded phases
//! simply converge faster there.
//!
//! [`FaultQueue`]: nvm::pmem::FaultQueue
//! [`Error::SwapFaultFailed`]: nvm::Error::SwapFaultFailed

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::{Duration, Instant};

use nvm::coordinator::experiments::{larger_than_dram, ExpConfig};
use nvm::mmd::{MmdConfig, MmdHandle, ThresholdPolicy};
use nvm::pmem::{BlockAllocator, FaultQueue, FaultQueueConfig, SwapPool};
use nvm::testutil::{FailingBacking, Rng};
use nvm::trees::{CompactTarget, TreeArray, TreeRegistry};
use nvm::Error;

/// 1 KB blocks keep trees multi-leaf at test sizes (u64 leaf_cap 128).
const BLOCK: usize = 1024;
const LEAF: usize = 128;

fn cfg_fast() -> MmdConfig {
    MmdConfig {
        interval: Duration::from_micros(100),
        tokens_per_tick: 16,
        trace_every: 16,
        ..MmdConfig::default()
    }
}

/// Writer stripe value for element `i` after `round` full passes.
fn wval(i: usize, round: u64) -> u64 {
    (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ round
}

/// The headline stress: 2 verifying readers + 1 striped writer against
/// a tree under enough pool pressure that the daemon must keep leaves
/// evicted, while an injector arms single-shot transient I/O failures
/// (always within the retry budget) and sub-millisecond completion
/// delays. Readers assert every value they see; the writer's stripe is
/// checksummed against the round counter at the end. Nothing here is
/// allowed to observe a transient fault.
#[test]
fn demand_fault_stress_under_flaky_backing() {
    let a = BlockAllocator::new(BLOCK, 64).unwrap();
    let nleaves = 24;
    let len = LEAF * nleaves;
    let mut tree: TreeArray<u64> = TreeArray::new(&a, len).unwrap();
    let data: Vec<u64> = (0..len).map(|i| (i as u64) << 8 | 0xA5).collect();
    tree.copy_from_slice(&data).unwrap();
    // Tree = 24 leaves + root = 25 blocks; scratch brings the pool to
    // 59/64 live (free 7.8% < the 8% eviction trigger), so the daemon
    // has standing pressure for the whole run.
    let scratch = a.alloc_many(34).unwrap();

    let (backing, ctl) = FailingBacking::new();
    let swap = SwapPool::with_backing(&a, backing);
    let q = FaultQueue::new(
        &swap,
        FaultQueueConfig {
            max_depth: 8,
            backoff_base: Duration::from_micros(50),
            backoff_cap: Duration::from_millis(2),
            ..FaultQueueConfig::default()
        },
    );
    // SAFETY: cleared below before `q` drops.
    unsafe { tree.install_faulter(&q) };
    let registry = TreeRegistry::new();
    // SAFETY: every accessor below is a fault-capable view/writer and
    // the faulter is installed.
    let id = unsafe { registry.register_evictable(&tree) };

    // [0, half) is read-only ground truth; [half, len) is the writer's.
    let half = len / 2;
    let stop = AtomicBool::new(false);
    let reads = AtomicU64::new(0);
    let reader_faults = AtomicU64::new(0);

    let (rounds, writer_faults, report) = std::thread::scope(|s| {
        let tree = &tree;
        let data = &data;
        let stop = &stop;
        let reads = &reads;
        let reader_faults = &reader_faults;
        let q = &q;

        q.attach_workers(s, 2);
        let d = MmdHandle::spawn_with_swap(
            s,
            &a,
            &registry,
            ThresholdPolicy::default(),
            cfg_fast(),
            q,
        );

        let mut readers = Vec::new();
        for t in 0..2u64 {
            readers.push(s.spawn(move || {
                let mut v = tree.view();
                let mut rng = Rng::new(0x51E55 + t);
                while !stop.load(Ordering::Acquire) {
                    let i = rng.below(half as u64) as usize;
                    let got = v.get(i).expect("transient faults must never reach readers");
                    assert_eq!(got, data[i], "reader saw a torn or lost value at {i}");
                    reads.fetch_add(1, Ordering::Relaxed);
                }
                reader_faults.fetch_add(v.faults(), Ordering::Relaxed);
            }));
        }

        let wr = s.spawn(move || {
            // SAFETY: sole writer; its stripe [half, len) is disjoint
            // from what the readers assert on, and the writer is
            // fault-capable by construction.
            let mut w = unsafe { tree.writer() };
            let mut rounds = 0u64;
            while !stop.load(Ordering::Acquire) {
                rounds += 1;
                for i in half..len {
                    w.set(i, wval(i, rounds))
                        .expect("transient faults must never reach the writer");
                }
            }
            (rounds, w.faults())
        });

        let ctl2 = ctl.clone();
        let injector = s.spawn(move || {
            let mut rng = Rng::new(0xFA11);
            while !stop.load(Ordering::Acquire) {
                // One transient failure somewhere in the next few I/Os:
                // single-shot, so the 4-attempt retry budget always
                // covers it.
                ctl2.fail_nth(1 + rng.below(4));
                if rng.chance(0.25) {
                    // Jitter completion ordering through the workers.
                    ctl2.delay_nth(1 + rng.below(3), Duration::from_micros(200));
                }
                std::thread::sleep(Duration::from_millis(2));
            }
            ctl2.disarm();
        });

        // Run until the queue has demonstrably served demand misses AND
        // retried at least one injected transient; the deadline only
        // bounds how long a genuinely broken build can hang the test.
        let deadline = Instant::now() + Duration::from_secs(30);
        while Instant::now() < deadline {
            let st = q.stats();
            if st.demand >= 40 && st.retries >= 1 {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        stop.store(true, Ordering::Release);
        for r in readers {
            r.join().unwrap();
        }
        let (rounds, writer_faults) = wr.join().unwrap();
        injector.join().unwrap();
        let report = d.shutdown();
        q.shutdown_workers();
        (rounds, writer_faults, report)
    });

    let st = q.stats();
    assert!(st.demand >= 40, "eviction under pressure must force demand faults: {st:?}");
    assert!(st.retries >= 1, "injected transients must exercise the retry path: {st:?}");
    assert_eq!(st.permanent, 0, "single-shot transients must never escalate: {st:?}");
    assert!(!q.degraded(), "queue must be healthy after transient-only faults");
    assert!(report.actions.evict > 0, "pressure must trigger eviction: {}", report.summary());
    assert_eq!(registry.swapped_out(), 0, "shutdown restores everything");
    assert!(reads.load(Ordering::Relaxed) > 0);
    assert!(
        reader_faults.load(Ordering::Relaxed) + writer_faults > 0,
        "accessors must have taken software page faults"
    );

    // Checksum against the mirror: reader half untouched, writer half
    // at its last completed round (0 full rounds leaves the seed data).
    let expected: Vec<u64> = (0..len)
        .map(|i| {
            if i < half || rounds == 0 {
                data[i]
            } else {
                wval(i, rounds)
            }
        })
        .collect();
    assert_eq!(tree.to_vec(), expected, "evict/fault churn corrupted the tree");

    registry.deregister(id);
    drop(registry);
    tree.clear_faulter();
    for b in scratch {
        a.free(b).unwrap();
    }
    a.epoch().synchronize(&a);
    drop(tree);
    drop(swap);
    assert_eq!(a.stats().allocated, 0);
}

/// Permanent-failure contract: when the backing stops serving reads,
/// demand faults burn the retry budget then surface
/// [`Error::SwapFaultFailed`] (view and writer alike), the queue goes
/// degraded, resident leaves keep serving, a daemon shutdown returns
/// with the degradation reported and the parked leaves *kept parked*
/// (never dropped) — and once the backing recovers, a plain restore
/// brings everything back bit-exact and clears the flag.
#[test]
fn permanent_failure_surfaces_typed_errors_and_recovers() {
    let a = BlockAllocator::new(BLOCK, 32).unwrap();
    let len = LEAF * 4;
    let mut tree: TreeArray<u64> = TreeArray::new(&a, len).unwrap();
    let data: Vec<u64> = (0..len).map(|i| (i as u64).wrapping_mul(31) | 1).collect();
    tree.copy_from_slice(&data).unwrap();

    let (backing, ctl) = FailingBacking::new();
    let swap = SwapPool::with_backing(&a, backing);
    let q = FaultQueue::new(
        &swap,
        FaultQueueConfig {
            max_retries: 3,
            backoff_base: Duration::from_micros(50),
            backoff_cap: Duration::from_micros(200),
            ..FaultQueueConfig::default()
        },
    );
    // SAFETY: cleared below before `q` drops.
    unsafe { tree.install_faulter(&q) };
    let registry = TreeRegistry::new();
    // SAFETY: accessors below are fault-capable views/writers.
    let id = unsafe { registry.register_evictable(&tree) };

    // Park leaves 0 and 1 while the backing is healthy.
    for leaf in 0..2 {
        // SAFETY: the register_evictable contract holds.
        unsafe { CompactTarget::evict_leaf(&tree, leaf, q.service()) }.unwrap();
    }
    assert_eq!(tree.swapped_leaves(), 2);

    // From here every swap read fails permanently.
    ctl.fail_always();
    let mut v = tree.view();
    match v.get(0) {
        Err(Error::SwapFaultFailed { attempts, .. }) => {
            assert_eq!(attempts, 3, "escalation happens exactly at the retry budget")
        }
        other => panic!("want SwapFaultFailed from the read hook, got {other:?}"),
    }
    assert!(q.degraded(), "permanent failure must mark the queue degraded");
    let st = q.stats();
    assert!(st.permanent >= 1, "{st:?}");
    assert!(st.retries >= 2, "retries precede escalation: {st:?}");
    // Resident leaves still serve — degradation is partial, not a wedge.
    assert_eq!(v.get(2 * LEAF).unwrap(), data[2 * LEAF]);
    // The writer hook surfaces the same typed error, and the failed set
    // is failure-atomic (asserted via the final checksum).
    // SAFETY: sole writer, fault-capable by construction.
    let mut w = unsafe { tree.writer() };
    match w.set(LEAF + 3, 7) {
        Err(Error::SwapFaultFailed { .. }) => {}
        other => panic!("want SwapFaultFailed from the write hook, got {other:?}"),
    }
    drop(w);
    drop(v);

    // A daemon shutdown over the degraded queue must return promptly
    // (restore attempts are bounded), surface the degradation in its
    // report, and leave the parked leaves parked rather than lose them.
    let report = std::thread::scope(|s| {
        let d = MmdHandle::spawn_with_swap(
            s,
            &a,
            &registry,
            ThresholdPolicy::default(),
            cfg_fast(),
            &q,
        );
        std::thread::sleep(Duration::from_millis(5));
        d.shutdown()
    });
    assert!(report.swap_degraded, "report must surface the degraded backing: {}", report.summary());
    assert_eq!(registry.swapped_out(), 2, "failed restores must keep leaves parked, not drop them");

    // Backing recovers: a plain restore through the queue brings both
    // leaves back and the first success clears the sticky flag.
    ctl.disarm();
    for leaf in 0..2 {
        assert!(CompactTarget::restore_leaf(&tree, leaf, &q).unwrap());
    }
    assert!(!q.degraded(), "first successful fault-in clears degradation");
    assert_eq!(tree.swapped_leaves(), 0);
    assert_eq!(tree.to_vec(), data, "parked payloads must survive the outage bit-exact");

    registry.deregister(id);
    drop(registry);
    tree.clear_faulter();
    a.epoch().synchronize(&a);
    drop(tree);
    drop(swap);
    assert_eq!(a.stats().allocated, 0);
}

/// The `larger-than-dram` experiment end-to-end at a quick sample: all
/// three rows (resident / paged / paged+flaky) run their full setup,
/// paging loop, and checksum teardown — the run functions carry their
/// own zero-panic / zero-escalation / bit-exact assertions, so this is
/// the experiment's whole acceptance contract in one call.
#[test]
fn larger_than_dram_experiment_end_to_end() {
    let cfg = ExpConfig {
        sample: 25_000,
        threads: 2,
        ..Default::default()
    };
    let t = larger_than_dram(&cfg);
    let demand = t.cell("2T paged+flaky", 1).expect("paged+flaky row present");
    assert!(demand > 0.0, "a larger-than-DRAM run must take demand faults");
    assert!(t.cell("2T resident", 0).expect("resident row present") > 0.0);
}

/// Per-tenant degraded scoping and recovery ordering (the PR 8
/// regression for "no global degraded state"): two tenants over one
/// fault queue, each with its own backing. A transient outage inside a
/// tenant's drain is absorbed by the probe's retry budget; an outage
/// past the budget degrades *only that tenant* — the healthy tenant
/// drains fully, its scoped flag stays clear — and the next drain's
/// probe notices the recovery, clears the flag, and brings the parked
/// leaves home bit-exact with every leaf restored exactly once (the
/// per-tenant fault counters are the double-restore oracle).
#[test]
fn tenant_backing_recovers_mid_drain_bit_exact_no_double_restore() {
    use nvm::mmd::Compactor;
    use nvm::pmem::{TenantConfig, TenantRegistry};
    let a = BlockAllocator::new(BLOCK, 64).unwrap();
    let tenants = TenantRegistry::new();
    let t1 = tenants.admit(TenantConfig::new(100, 100));
    let t2 = tenants.admit(TenantConfig::new(100, 100));
    // Seed residency so eviction credits have a balance to draw down
    // (real flows charge allocations through a QuotaAlloc).
    for _ in 0..4 {
        tenants.fault_charged(t1.id());
        tenants.fault_charged(t2.id());
    }
    let swap1 = SwapPool::anonymous(&a).unwrap();
    let (fb, ctl) = FailingBacking::new();
    let swap2 = SwapPool::with_backing(&a, fb);
    let q = FaultQueue::with_tenants(
        &swap1,
        FaultQueueConfig {
            max_retries: 3,
            backoff_base: Duration::from_micros(50),
            backoff_cap: Duration::from_micros(400),
            ..FaultQueueConfig::default()
        },
        &tenants,
    );
    q.route_tenant(t2.id(), &swap2);

    let mut tree1: TreeArray<u64> = TreeArray::new(&a, LEAF * 4).unwrap();
    let mut tree2: TreeArray<u64> = TreeArray::new(&a, LEAF * 4).unwrap();
    let d1: Vec<u64> = (0..LEAF * 4).map(|i| (i as u64).wrapping_mul(7) ^ 0x0F0F).collect();
    let d2: Vec<u64> = (0..LEAF * 4).map(|i| (i as u64).wrapping_mul(11) ^ 0xF0F0).collect();
    tree1.copy_from_slice(&d1).unwrap();
    tree2.copy_from_slice(&d2).unwrap();
    let registry = TreeRegistry::new();
    // SAFETY: no accessors race the compactor in this test.
    let id1 = unsafe { registry.register_evictable_for_tenant(&tree1, t1.id()) };
    let id2 = unsafe { registry.register_evictable_for_tenant(&tree2, t2.id()) };
    let mut c = Compactor::new(&a, &registry);

    // Phase 1 — a transient outage *inside* the drain: the burst ends
    // within one probe's retry budget, so nothing degrades and the
    // drain completes in one call.
    assert_eq!(c.evict_tenants(usize::MAX, &q, &tenants), 8);
    ctl.fail_for(2); // max_retries = 3 absorbs it
    assert_eq!(c.restore_all_tenants(&q, &tenants), 8);
    assert!(!q.degraded() && !q.degraded_for(t2.id()));
    assert_eq!(registry.swapped_out(), 0);
    assert_eq!(t1.snapshot().faults, 4, "each leaf faulted exactly once");
    assert_eq!(t2.snapshot().faults, 4, "each leaf faulted exactly once");
    assert_eq!(tree1.to_vec(), d1);
    assert_eq!(tree2.to_vec(), d2);

    // Phase 2 — an outage past the budget: this drain burns one probe
    // (3 failed attempts), degrades ONLY t2, and still brings every one
    // of t1's leaves home. The healthy tenant never sees a flag.
    assert_eq!(c.evict_tenants(usize::MAX, &q, &tenants), 8);
    ctl.fail_for(5); // 3 fail this drain's probe, 2 the next's — then recovered
    assert_eq!(c.restore_all_tenants(&q, &tenants), 4, "t1 home, t2 contained");
    assert!(q.degraded_for(t2.id()));
    assert!(!q.degraded_for(t1.id()), "degradation must be scoped, not global");
    assert!(q.degraded(), "the aggregate view still reports the sick tenant");
    assert!(t2.snapshot().degraded, "registry mirrors the scoped flag");
    assert_eq!(registry.swapped_out_for(t1.id()), 0);
    assert_eq!(registry.swapped_out_for(t2.id()), 4);

    // The next drain probes t2, the outage ends inside that probe's
    // retry burst, the flag clears, and the rest restores — each leaf
    // exactly once across the two drains.
    assert_eq!(c.restore_all_tenants(&q, &tenants), 4);
    assert!(!q.degraded() && !q.degraded_for(t2.id()));
    assert!(!t2.snapshot().degraded);
    assert_eq!(registry.swapped_out(), 0);
    assert_eq!(t1.snapshot().faults, 8, "no t1 leaf restored twice");
    assert_eq!(t2.snapshot().faults, 8, "no t2 leaf restored twice");
    assert_eq!(tree1.to_vec(), d1);
    assert_eq!(tree2.to_vec(), d2, "recovery mid-drain must be bit-exact");

    registry.deregister(id1);
    registry.deregister(id2);
    drop(registry);
    a.epoch().synchronize(&a);
    drop((tree1, tree2));
    drop((swap1, swap2));
    assert_eq!(a.stats().allocated, 0);
}

/// Completion-ordering: four requester threads demand-fault disjoint
/// leaves through two queue workers while every backing I/O carries a
/// delay and one early I/O fails transiently — completions come back in
/// an order unrelated to requests, and none of that is observable:
/// every read is correct, every leaf ends resident, nothing escalates.
#[test]
fn worker_completions_reorder_without_loss() {
    let a = BlockAllocator::new(BLOCK, 64).unwrap();
    let nleaves = 8;
    let len = LEAF * nleaves;
    let mut tree: TreeArray<u64> = TreeArray::new(&a, len).unwrap();
    let data: Vec<u64> = (0..len).map(|i| (i as u64) ^ 0x5A5A).collect();
    tree.copy_from_slice(&data).unwrap();

    let (backing, ctl) = FailingBacking::new();
    let swap = SwapPool::with_backing(&a, backing);
    let q = FaultQueue::new(&swap, FaultQueueConfig::default());
    // SAFETY: cleared below before `q` drops.
    unsafe { tree.install_faulter(&q) };
    let registry = TreeRegistry::new();
    // SAFETY: accessors below are fault-capable views.
    let id = unsafe { registry.register_evictable(&tree) };
    for leaf in 0..nleaves {
        // SAFETY: the register_evictable contract holds.
        unsafe { CompactTarget::evict_leaf(&tree, leaf, q.service()) }.unwrap();
    }
    assert_eq!(tree.swapped_leaves(), nleaves);

    // Slow every backing read and fail one of the first few: with two
    // workers serving four requesters the completion order diverges
    // from request order, and the transient is retried behind the
    // scenes.
    ctl.delay_all(Duration::from_micros(300));
    ctl.fail_nth(2);

    let faults: u64 = std::thread::scope(|s| {
        let tree = &tree;
        let data = &data;
        q.attach_workers(s, 2);
        let mut hs = Vec::new();
        for t in 0..4usize {
            hs.push(s.spawn(move || {
                let mut v = tree.view();
                for leaf in [t, t + 4] {
                    for i in (leaf * LEAF..(leaf + 1) * LEAF).step_by(17) {
                        assert_eq!(v.get(i).unwrap(), data[i], "reordered completion lost data");
                    }
                }
                v.faults()
            }));
        }
        let faults = hs.into_iter().map(|h| h.join().unwrap()).sum();
        q.shutdown_workers();
        faults
    });
    ctl.disarm();

    assert!(faults >= nleaves as u64, "each parked leaf must fault in: {faults}");
    assert_eq!(tree.swapped_leaves(), 0);
    let st = q.stats();
    assert!(st.retries >= 1, "the injected transient must have been retried: {st:?}");
    assert_eq!(st.permanent, 0, "{st:?}");
    assert!(!q.degraded());
    assert_eq!(tree.to_vec(), data);

    registry.deregister(id);
    drop(registry);
    tree.clear_faulter();
    a.epoch().synchronize(&a);
    drop(tree);
    drop(swap);
    assert_eq!(a.stats().allocated, 0);
}
