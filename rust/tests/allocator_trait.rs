//! Allocator-trait conformance: the same invariant suite runs against
//! every [`BlockAlloc`] implementation (the mutex baseline, the sharded
//! lock-free allocator, and the two-level reserving allocator), plus a
//! multi-thread ownership stress test asserting no block is ever handed
//! to two owners, and two-level-specific reservation-handoff checks.

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;

use nvm::pmem::{
    BlockAlloc, BlockAllocator, BlockId, ShardedAllocator, TwoLevelAllocator, SUBTREE_BLOCKS,
};
use nvm::testutil::forall;

/// Run `f` against every allocator implementation at the same geometry.
fn with_each_allocator(block_size: usize, capacity: usize, f: impl Fn(&dyn Named)) {
    let mutex = MutexImpl(BlockAllocator::new(block_size, capacity).unwrap());
    f(&mutex);
    let sharded = ShardedImpl(ShardedAllocator::with_shards(block_size, capacity, 4).unwrap());
    f(&sharded);
    let nodes = capacity.div_ceil(SUBTREE_BLOCKS).min(2);
    let twolevel =
        TwoLevelImpl(TwoLevelAllocator::with_topology(block_size, capacity, nodes, 4).unwrap());
    f(&twolevel);
}

/// Object-safe shim: the invariant suite only needs the safe subset of
/// the trait, so it can run through a `&dyn` without monomorphizing the
/// whole suite twice.
trait Named {
    fn name(&self) -> &'static str;
    fn alloc(&self) -> nvm::Result<BlockId>;
    fn alloc_many(&self, n: usize) -> nvm::Result<Vec<BlockId>>;
    fn free(&self, id: BlockId) -> nvm::Result<()>;
    fn free_blocks(&self) -> usize;
    fn allocated(&self) -> usize;
    fn capacity(&self) -> usize;
    fn is_live(&self, id: BlockId) -> bool;
    fn write(&self, id: BlockId, offset: usize, data: &[u8]) -> nvm::Result<()>;
    fn read(&self, id: BlockId, offset: usize, out: &mut [u8]) -> nvm::Result<()>;
    fn alloc_in_span(&self, lo: usize, hi: usize) -> nvm::Result<BlockId>;
    fn live_snapshot(&self, out: &mut Vec<u64>);
}

struct MutexImpl(BlockAllocator);
struct ShardedImpl(ShardedAllocator);
struct TwoLevelImpl(TwoLevelAllocator);

macro_rules! forward {
    ($ty:ty, $label:literal) => {
        impl Named for $ty {
            fn name(&self) -> &'static str {
                $label
            }
            fn alloc(&self) -> nvm::Result<BlockId> {
                BlockAlloc::alloc(&self.0)
            }
            fn alloc_many(&self, n: usize) -> nvm::Result<Vec<BlockId>> {
                BlockAlloc::alloc_many(&self.0, n)
            }
            fn free(&self, id: BlockId) -> nvm::Result<()> {
                BlockAlloc::free(&self.0, id)
            }
            fn free_blocks(&self) -> usize {
                BlockAlloc::free_blocks(&self.0)
            }
            fn allocated(&self) -> usize {
                BlockAlloc::stats(&self.0).allocated
            }
            fn capacity(&self) -> usize {
                BlockAlloc::capacity(&self.0)
            }
            fn is_live(&self, id: BlockId) -> bool {
                BlockAlloc::is_live(&self.0, id)
            }
            fn write(&self, id: BlockId, offset: usize, data: &[u8]) -> nvm::Result<()> {
                BlockAlloc::write(&self.0, id, offset, data)
            }
            fn read(&self, id: BlockId, offset: usize, out: &mut [u8]) -> nvm::Result<()> {
                BlockAlloc::read(&self.0, id, offset, out)
            }
            fn alloc_in_span(&self, lo: usize, hi: usize) -> nvm::Result<BlockId> {
                BlockAlloc::alloc_in_span(&self.0, lo, hi)
            }
            fn live_snapshot(&self, out: &mut Vec<u64>) {
                BlockAlloc::live_snapshot(&self.0, out)
            }
        }
    };
}

forward!(MutexImpl, "mutex");
forward!(ShardedImpl, "sharded");
forward!(TwoLevelImpl, "twolevel");

#[test]
fn prop_alloc_free_roundtrip_and_conservation() {
    forall(30, |g| {
        let cap = g.usize_in(1, 96);
        with_each_allocator(1024, cap, |a| {
            let mut g = nvm::testutil::Rng::new(cap as u64 ^ 0xA110C);
            let mut live: Vec<BlockId> = Vec::new();
            for _ in 0..200 {
                if g.chance(0.45) && !live.is_empty() {
                    let i = g.range(0, live.len());
                    let b = live.swap_remove(i);
                    a.free(b).unwrap_or_else(|e| panic!("{}: free: {e}", a.name()));
                    assert!(!a.is_live(b), "{}: freed block still live", a.name());
                } else if let Ok(b) = a.alloc() {
                    assert!(a.is_live(b), "{}: fresh block not live", a.name());
                    live.push(b);
                }
                // Conservation: allocated + free == capacity, always.
                assert_eq!(
                    a.allocated() + a.free_blocks(),
                    a.capacity(),
                    "{}: conservation violated",
                    a.name()
                );
                assert_eq!(a.allocated(), live.len(), "{}: live count drift", a.name());
            }
        });
    });
}

#[test]
fn prop_double_free_rejected() {
    forall(20, |g| {
        let cap = g.usize_in(2, 64);
        with_each_allocator(1024, cap, |a| {
            let b = a.alloc().unwrap();
            a.free(b).unwrap();
            assert!(a.free(b).is_err(), "{}: double free accepted", a.name());
            // The failed free must not corrupt the pool.
            assert_eq!(a.allocated(), 0, "{}", a.name());
            assert_eq!(a.free_blocks(), a.capacity(), "{}", a.name());
        });
    });
}

#[test]
fn prop_alloc_many_rollback_leaks_nothing() {
    forall(25, |g| {
        let cap = g.usize_in(2, 80);
        let held = g.usize_in(1, cap);
        with_each_allocator(1024, cap, |a| {
            let keep = a.alloc_many(held).unwrap();
            // More than remains: must fail AND leak nothing.
            let want = cap - held + 1;
            assert!(a.alloc_many(want).is_err(), "{}", a.name());
            assert_eq!(
                a.free_blocks(),
                cap - held,
                "{}: rollback leaked blocks",
                a.name()
            );
            // The remainder is still fully allocatable.
            let rest = a.alloc_many(cap - held).unwrap();
            assert_eq!(rest.len(), cap - held, "{}", a.name());
            for b in keep.into_iter().chain(rest) {
                a.free(b).unwrap();
            }
            assert_eq!(a.free_blocks(), cap, "{}", a.name());
        });
    });
}

#[test]
fn prop_distinct_blocks_never_alias() {
    forall(15, |g| {
        let cap = g.usize_in(2, 48);
        with_each_allocator(1024, cap, |a| {
            let blocks = a.alloc_many(cap).unwrap();
            for (i, b) in blocks.iter().enumerate() {
                a.write(*b, 0, &[i as u8; 64]).unwrap();
            }
            for (i, b) in blocks.iter().enumerate() {
                let mut out = [0u8; 64];
                a.read(*b, 0, &mut out).unwrap();
                assert_eq!(out, [i as u8; 64], "{}: block data bled", a.name());
            }
        });
    });
}

/// The central concurrency guarantee: under 8 threads of churn on a
/// deliberately small pool (forcing contention, shard exhaustion, and
/// steals), no block is ever owned by two threads at once. Ownership is
/// tracked in an external claim table that every alloc/free transition
/// must pass through atomically.
fn two_owner_stress<A: BlockAlloc + 'static>(alloc: A, label: &str) {
    const THREADS: u32 = 8;
    const ITERS: usize = 3_000;
    let capacity = alloc.capacity();
    let alloc = Arc::new(alloc);
    let claims: Arc<Vec<AtomicU32>> = Arc::new((0..capacity).map(|_| AtomicU32::new(0)).collect());
    let mut handles = Vec::new();
    for tid in 1..=THREADS {
        let alloc = alloc.clone();
        let claims = claims.clone();
        handles.push(std::thread::spawn(move || {
            let mut held: Vec<BlockId> = Vec::new();
            for i in 0..ITERS {
                if (i + tid as usize) % 3 != 0 || held.is_empty() {
                    if let Ok(b) = alloc.alloc() {
                        // Claim must have been unowned: two owners would
                        // mean the allocator double-handed the block.
                        let prev = claims[b.0 as usize].swap(tid, Ordering::AcqRel);
                        assert_eq!(prev, 0, "block {} handed to two owners", b.0);
                        held.push(b);
                    }
                } else {
                    let b = held.pop().unwrap();
                    let prev = claims[b.0 as usize].swap(0, Ordering::AcqRel);
                    assert_eq!(prev, tid, "claim table corrupted for block {}", b.0);
                    alloc.free(b).unwrap();
                }
            }
            for b in held {
                let prev = claims[b.0 as usize].swap(0, Ordering::AcqRel);
                assert_eq!(prev, tid);
                alloc.free(b).unwrap();
            }
        }));
    }
    for h in handles {
        h.join().unwrap_or_else(|_| panic!("{label}: stress thread panicked"));
    }
    assert_eq!(alloc.stats().allocated, 0, "{label}: blocks leaked");
    assert_eq!(alloc.free_blocks(), capacity, "{label}");
    assert!(
        claims.iter().all(|c| c.load(Ordering::Acquire) == 0),
        "{label}: claim table not drained"
    );
}

#[test]
fn stress_no_block_has_two_owners_mutex() {
    // Pool far smaller than peak demand: allocation failures and reuse
    // are constant, which is exactly what the test wants.
    two_owner_stress(BlockAllocator::new(1024, 96).unwrap(), "mutex");
}

#[test]
fn stress_no_block_has_two_owners_sharded() {
    two_owner_stress(
        ShardedAllocator::with_shards(1024, 96, 4).unwrap(),
        "sharded",
    );
}

#[test]
fn stress_no_block_has_two_owners_twolevel() {
    // Tiny single-subtree pool: every thread fights over one bitfield
    // and the reservation path collapses to the shared fallback.
    two_owner_stress(
        TwoLevelAllocator::with_topology(1024, 96, 1, 8).unwrap(),
        "twolevel-small",
    );
    // Multi-subtree, multi-node pool: reservations, handoffs, and
    // cross-node refills all run under the same claim-table scrutiny.
    two_owner_stress(
        TwoLevelAllocator::with_topology(1024, 1280, 2, 8).unwrap(),
        "twolevel-numa",
    );
}

#[test]
fn prop_alloc_in_span_returns_lowest_free_in_range() {
    forall(12, |g| {
        let cap = g.usize_in(8, 96);
        let seed = g.usize_in(0, 1 << 20) as u64;
        with_each_allocator(1024, cap, |a| {
            let _all = a.alloc_many(cap).unwrap();
            let mut rng = nvm::testutil::Rng::new(seed ^ 0x5BA9);
            // Fragment: free a random subset (ids are dense 0..cap).
            let freed: Vec<usize> = (0..cap).filter(|_| rng.chance(0.4)).collect();
            for &i in &freed {
                a.free(BlockId(i as u32)).unwrap();
            }
            for _ in 0..20 {
                let lo = rng.range(0, cap);
                let hi = lo + 1 + rng.range(0, cap - lo);
                let want = freed.iter().copied().find(|&i| lo <= i && i < hi);
                match (a.alloc_in_span(lo, hi), want) {
                    (Ok(b), Some(w)) => {
                        assert_eq!(
                            b.0 as usize, w,
                            "{}: alloc_in_span({lo},{hi}) not lowest free",
                            a.name()
                        );
                        a.free(b).unwrap();
                    }
                    (Err(_), None) => {}
                    (got, want) => panic!(
                        "{}: alloc_in_span({lo},{hi}) = {got:?}, expected free id {want:?}",
                        a.name()
                    ),
                }
            }
        });
    });
}

#[test]
fn prop_live_snapshot_matches_is_live_under_churn() {
    forall(10, |g| {
        let cap = g.usize_in(4, 90);
        let seed = g.usize_in(0, 1 << 20) as u64;
        with_each_allocator(1024, cap, |a| {
            let mut rng = nvm::testutil::Rng::new(seed ^ 0xB17);
            let mut live: Vec<BlockId> = Vec::new();
            for step in 0..150 {
                if rng.chance(0.4) && !live.is_empty() {
                    let i = rng.range(0, live.len());
                    a.free(live.swap_remove(i)).unwrap();
                } else if let Ok(b) = a.alloc() {
                    live.push(b);
                }
                if step % 25 != 0 {
                    continue;
                }
                let mut words = Vec::new();
                a.live_snapshot(&mut words);
                assert_eq!(words.len(), cap.div_ceil(64), "{}", a.name());
                for i in 0..cap {
                    let bit = words[i / 64] >> (i % 64) & 1 == 1;
                    assert_eq!(
                        bit,
                        a.is_live(BlockId(i as u32)),
                        "{}: snapshot bit {i} disagrees with is_live",
                        a.name()
                    );
                }
                // Bits past the capacity stay zero.
                if cap % 64 != 0 {
                    assert_eq!(words[cap / 64] >> (cap % 64), 0, "{}", a.name());
                }
            }
        });
    });
}

#[test]
fn twolevel_reservation_hands_off_when_a_subtree_drains() {
    // Two subtrees, two cores: each core's first allocation reserves a
    // distinct subtree. Draining the whole pool through core 1 must
    // then hand off into core 0's reservation rather than fail, and
    // both the refills (reservations) and steals (handoffs) surface in
    // the contention stats.
    let cap = 2 * SUBTREE_BLOCKS;
    let a = TwoLevelAllocator::with_topology(1024, cap, 1, 2).unwrap();
    let b0 = a.alloc_core_on(0, 0).unwrap();
    let b1 = a.alloc_core_on(1, 0).unwrap();
    assert_ne!(
        b0.0 as usize / SUBTREE_BLOCKS,
        b1.0 as usize / SUBTREE_BLOCKS,
        "cores must reserve distinct subtrees"
    );
    let mut held = vec![b0, b1];
    while let Ok(b) = a.alloc_core_on(1, 0) {
        held.push(b);
    }
    assert_eq!(held.len(), cap, "core 1 must drain the pool via handoff");
    let c = a.contention();
    assert!(c.refills >= 2, "each core's reservation is a refill: {c:?}");
    assert!(c.steals > 0, "draining past the reservation implies handoffs: {c:?}");
    for b in held {
        a.free(b).unwrap();
    }
    assert_eq!(a.free_blocks(), cap);
    assert_eq!(a.stats().allocated, 0);
}

#[test]
fn sharded_steals_surface_in_contention_stats() {
    // One thread draining a multi-shard pool must cross shards.
    let a = ShardedAllocator::with_shards(1024, 256, 4).unwrap();
    let all = a.alloc_many(256).unwrap();
    assert!(a.contention().steals > 0, "draining 4 shards implies steals");
    for b in all {
        a.free(b).unwrap();
    }
    // No cas_retries assertion: compare_exchange_weak may fail
    // spuriously on LL/SC architectures even without contention.
}
