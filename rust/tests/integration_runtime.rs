//! Integration: the AOT artifact → PJRT → Rust path (requires
//! `make artifacts`; tests are skipped with a message if missing).

use nvm::coordinator::BlockBatcher;
use nvm::pmem::BlockAllocator;
use nvm::runtime::{Artifacts, Engine, Input};
use nvm::testutil::Rng;
use nvm::trees::TreeArray;
use nvm::workloads::blackscholes as bs;
use nvm::BLOCK_ELEMS_F32 as BELE;

fn engine() -> Option<Engine> {
    match Engine::new() {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("SKIP runtime integration: {e}");
            None
        }
    }
}

#[test]
fn artifacts_manifest_complete() {
    let Ok(a) = Artifacts::discover() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    for name in [
        "bs_blocked_256x8192",
        "bs_blocked_1x8192",
        "bs_contig_2097152",
        "bs_greeks_blocked_16x8192",
        "gups_1048576_4096",
        "tree_gather_64x8192_4096",
    ] {
        assert!(a.spec(name).is_some(), "missing artifact {name}");
        assert!(a.hlo_path(name).is_ok(), "missing HLO file for {name}");
    }
}

#[test]
fn blocked_kernel_matches_rust_scalar() {
    let Some(engine) = engine() else { return };
    let mut rng = Rng::new(1);
    let spot: Vec<f32> = (0..BELE).map(|_| rng.f32_range(5.0, 200.0)).collect();
    let strike: Vec<f32> = (0..BELE).map(|_| rng.f32_range(5.0, 200.0)).collect();
    let tmat: Vec<f32> = (0..BELE).map(|_| rng.f32_range(0.05, 3.0)).collect();
    let shape = vec![1i64, BELE as i64];
    let out = engine
        .run_f32(
            "bs_blocked_1x8192",
            &[
                Input::F32(&spot, shape.clone()),
                Input::F32(&strike, shape.clone()),
                Input::F32(&tmat, shape),
                Input::ScalarF32(0.03),
                Input::ScalarF32(0.25),
            ],
        )
        .expect("execute");
    assert_eq!(out.len(), 2, "call + put outputs");
    for i in (0..BELE).step_by(101) {
        let (c, p) = bs::price(
            bs::Option1 { spot: spot[i], strike: strike[i], tmat: tmat[i] },
            0.03,
            0.25,
        );
        assert!((out[0][i] - c).abs() < 1e-2, "call[{i}]: {} vs {c}", out[0][i]);
        assert!((out[1][i] - p).abs() < 1e-2, "put[{i}]: {} vs {p}", out[1][i]);
    }
}

#[test]
fn executables_compile_once() {
    let Some(engine) = engine() else { return };
    let mut rng = Rng::new(2);
    let spot: Vec<f32> = (0..BELE).map(|_| rng.f32_range(5.0, 200.0)).collect();
    let shape = vec![1i64, BELE as i64];
    for _ in 0..3 {
        engine
            .run_f32(
                "bs_blocked_1x8192",
                &[
                    Input::F32(&spot, shape.clone()),
                    Input::F32(&spot, shape.clone()),
                    Input::F32(&spot, shape.clone()),
                    Input::ScalarF32(0.03),
                    Input::ScalarF32(0.25),
                ],
            )
            .expect("execute");
    }
    assert_eq!(engine.compile_count(), 1, "must compile once, run many");
}

#[test]
fn batcher_prices_trees_end_to_end() {
    let Some(engine) = engine() else { return };
    // Non-multiple of the batch to exercise tail padding, and more than
    // one leaf to exercise gather/scatter.
    let n = 3 * BELE + 1234;
    let alloc = BlockAllocator::with_capacity_bytes(n * 4 * 6 + (8 << 20)).unwrap();
    let (spot_v, strike_v, tmat_v) = bs::synth_portfolio(n, 9);
    let mut spot: TreeArray<f32> = TreeArray::new(&alloc, n).unwrap();
    let mut strike: TreeArray<f32> = TreeArray::new(&alloc, n).unwrap();
    let mut tmat: TreeArray<f32> = TreeArray::new(&alloc, n).unwrap();
    spot.copy_from_slice(&spot_v).unwrap();
    strike.copy_from_slice(&strike_v).unwrap();
    tmat.copy_from_slice(&tmat_v).unwrap();
    let mut call: TreeArray<f32> = TreeArray::new(&alloc, n).unwrap();
    let mut put: TreeArray<f32> = TreeArray::new(&alloc, n).unwrap();

    let mut batcher = BlockBatcher::new(&engine);
    let stats = batcher
        .price_trees(&spot, &strike, &tmat, 0.03, 0.25, &mut call, &mut put)
        .expect("batch");
    assert_eq!(stats.dispatches, 1);
    assert!(stats.padded > 0, "tail batch must be padded");

    let call_v = call.to_vec();
    let put_v = put.to_vec();
    for i in (0..n).step_by(503) {
        let (c, p) = bs::price(
            bs::Option1 { spot: spot_v[i], strike: strike_v[i], tmat: tmat_v[i] },
            0.03,
            0.25,
        );
        assert!((call_v[i] - c).abs() < 1e-2, "call[{i}]");
        assert!((put_v[i] - p).abs() < 1e-2, "put[{i}]");
    }
}

#[test]
fn gups_artifact_matches_rust() {
    let Some(engine) = engine() else { return };
    let n = 1usize << 20;
    let m = 4096usize;
    let mut rng = Rng::new(4);
    let table: Vec<i32> = (0..n).map(|_| rng.next_u32() as i32).collect();
    let idx: Vec<i32> = rng
        .distinct(m, n)
        .into_iter()
        .map(|v| v as i32)
        .collect();
    let keys: Vec<i32> = (0..m).map(|_| rng.next_u32() as i32).collect();
    let out = engine
        .run_i32(
            "gups_1048576_4096",
            &[
                Input::I32(&table, vec![n as i64]),
                Input::I32(&idx, vec![m as i64]),
                Input::I32(&keys, vec![m as i64]),
            ],
        )
        .expect("execute gups");
    let mut expect = table.clone();
    for (j, &i) in idx.iter().enumerate() {
        expect[i as usize] ^= keys[j];
    }
    assert_eq!(out[0], expect, "GUPS artifact must equal Rust xor-scatter");
}

#[test]
fn tree_gather_artifact_matches_tree_array() {
    let Some(engine) = engine() else { return };
    // The artifact implements the same indirection the Rust TreeArray
    // uses: flat index -> (leaf, offset). Cross-validate them.
    let nblocks = 64usize;
    let n = nblocks * BELE;
    let m = 4096usize;
    let alloc = BlockAllocator::with_capacity_bytes(n * 4 + (8 << 20)).unwrap();
    let mut rng = Rng::new(5);
    let data: Vec<f32> = (0..n).map(|_| rng.f32_range(-10.0, 10.0)).collect();
    let mut tree: TreeArray<f32> = TreeArray::new(&alloc, n).unwrap();
    tree.copy_from_slice(&data).unwrap();
    let idx: Vec<i32> = (0..m).map(|_| rng.range(0, n) as i32).collect();

    let out = engine
        .run_f32(
            "tree_gather_64x8192_4096",
            &[
                Input::F32(&data, vec![nblocks as i64, BELE as i64]),
                Input::I32(&idx, vec![m as i64]),
            ],
        )
        .expect("execute tree_gather");
    for (j, &i) in idx.iter().enumerate() {
        let via_tree = tree.get(i as usize).unwrap();
        assert_eq!(out[0][j], via_tree, "gather[{j}] (idx {i})");
        assert_eq!(out[0][j], data[i as usize]);
    }
}

#[test]
fn greeks_artifact_sane() {
    let Some(engine) = engine() else { return };
    let nblocks = 16usize;
    let n = nblocks * BELE;
    let mut rng = Rng::new(6);
    let spot: Vec<f32> = (0..n).map(|_| rng.f32_range(20.0, 180.0)).collect();
    let strike: Vec<f32> = (0..n).map(|_| rng.f32_range(20.0, 180.0)).collect();
    let tmat: Vec<f32> = (0..n).map(|_| rng.f32_range(0.1, 2.0)).collect();
    let shape = vec![nblocks as i64, BELE as i64];
    let out = engine
        .run_f32(
            "bs_greeks_blocked_16x8192",
            &[
                Input::F32(&spot, shape.clone()),
                Input::F32(&strike, shape.clone()),
                Input::F32(&tmat, shape),
                Input::ScalarF32(0.03),
                Input::ScalarF32(0.25),
            ],
        )
        .expect("execute greeks");
    // Delta of a call is in (0, 1); vega is positive.
    let delta = &out[0];
    assert_eq!(delta.len(), n);
    for i in (0..n).step_by(811) {
        assert!(
            (-1e-3..=1.001).contains(&delta[i]),
            "delta[{i}] = {} out of range",
            delta[i]
        );
    }
    let vega = out[1][0];
    assert!(vega > 0.0, "book vega {vega} must be positive");
}
