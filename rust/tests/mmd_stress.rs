//! Stress tests for the memory-management daemon: the mmd compacts a
//! deliberately fragmented pool while reader views verify checksums
//! against a contiguous mirror and a churn thread keeps perforating the
//! free space — the acceptance scenario of the mmd PR.
//!
//! The hazard stack is everything PR 3 built plus the daemon on top: a
//! background thread relocating leaves with placement-directed
//! destinations, reclaiming displaced blocks through the arena epoch,
//! while three kinds of mutation race it (view reads, allocator churn,
//! its own reclaim). A stale or torn read anywhere shows up as a
//! checksum mismatch; a lost or double-freed block as an allocation
//! count mismatch at teardown.
//!
//! Run in `--release` too (CI does): the interesting interleavings
//! rarely open up at debug-build speeds.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use nvm::mmd::{FragSampler, MmdConfig, MmdHandle, ThresholdPolicy};
use nvm::pmem::{BlockAlloc, BlockAllocator, ShardedAllocator};
use nvm::testutil::{fragmented_tree, Rng};
use nvm::trees::TreeRegistry;
use nvm::workloads::hashprobe;

const BLOCK: usize = 1024; // u64: 128 elems/leaf, fanout 128
const CAP: usize = 512;
const LEAVES: usize = 96;

/// Three readers verify every value against the mirror while the daemon
/// compacts and a churn thread fragments; then the pool must end packed,
/// intact, and leak-free.
fn compaction_stress<A: BlockAlloc>(a: &A) {
    let (tree, mirror) = fragmented_tree(a, LEAVES, |i| {
        i.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1
    });
    let mut sampler = FragSampler::new();
    let s0 = sampler.sample(a).score;
    assert!(s0 > 0.5, "setup must fragment the pool: {s0}");

    let registry = TreeRegistry::new();
    // SAFETY: until deregistration the tree is read only through
    // epoch-registered views; no writes, no raw slices; the daemon is
    // the only migrator.
    let reg_id = unsafe { registry.register(&tree) };

    // Readers verify in *rounds* until told to stop; each computes its
    // own per-round reference from the immutable mirror. Choreography
    // (all polled with generous deadlines, never fixed sleeps — the
    // overlap must hold on arbitrarily loaded CI machines):
    //   1. readers + churn start; wait until every reader has finished
    //      a round (its TLB holds valid entries);
    //   2. only then spawn the daemon, and keep the readers running
    //      until ≥ 32 relocations were published (epoch delta) — so
    //      shootdowns provably land on warm reader TLBs;
    //   3. stop the readers, then the churn, then let the daemon pack
    //      the quiet pool and shut it down.
    let ops_round: u64 = if cfg!(debug_assertions) { 20_000 } else { 100_000 };
    let stop_readers = AtomicBool::new(false);
    let stop_churn = AtomicBool::new(false);
    let warm = AtomicUsize::new(0);
    let (tree_r, mirror_r, stop_readers_r, stop_churn_r, warm_r) =
        (&tree, &mirror, &stop_readers, &stop_churn, &warm);

    let report = std::thread::scope(|s| {
        let readers: Vec<_> = (0..3usize)
            .map(|tid| {
                s.spawn(move || {
                    let mut view = tree_r.view();
                    let mut round = 0u64;
                    loop {
                        let seed = 0xBEE5 ^ ((tid as u64) << 24) ^ (round << 1);
                        let want = hashprobe::probe_read_reference(mirror_r, ops_round, seed);
                        let got = hashprobe::probe_view(&mut view, ops_round, seed);
                        assert_eq!(
                            got, want,
                            "reader {tid} observed a stale/torn value during compaction \
                             (round {round})"
                        );
                        if round == 0 {
                            warm_r.fetch_add(1, Ordering::Release);
                        }
                        round += 1;
                        if stop_readers_r.load(Ordering::Relaxed) {
                            break;
                        }
                    }
                    view.tlb_stats()
                })
            })
            .collect();
        // Churn: allocate-and-scribble free blocks so a stale
        // translation that escaped the epoch protocol would read
        // garbage, then free them again, keeping the free space moving.
        let churn = s.spawn(move || {
            let mut rng = Rng::new(0x51ED);
            let mut held = Vec::new();
            while !stop_churn_r.load(Ordering::Relaxed) {
                if held.len() < 24 {
                    if let Ok(b) = a.alloc() {
                        a.write(b, 0, &[0xA5u8; BLOCK]).unwrap();
                        held.push(b);
                    }
                }
                if held.len() >= 24 || (!held.is_empty() && rng.range(0, 3) == 0) {
                    let i = rng.range(0, held.len());
                    a.free(held.swap_remove(i)).unwrap();
                }
            }
            for b in held {
                a.free(b).unwrap();
            }
        });
        // Per-phase deadlines: a slow early phase must not starve the
        // later ones (each bound only limits how long a genuinely
        // broken daemon can hang the test).
        let mut deadline = Instant::now() + Duration::from_secs(30);
        while warm.load(Ordering::Acquire) < 3 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(warm.load(Ordering::Acquire), 3, "readers never warmed up");
        let e0 = a.epoch().current();
        let daemon = MmdHandle::spawn(
            s,
            a,
            &registry,
            ThresholdPolicy::default(),
            MmdConfig {
                interval: Duration::from_micros(100),
                tokens_per_tick: 16,
                ..MmdConfig::default()
            },
        );
        deadline = Instant::now() + Duration::from_secs(30);
        while a.epoch().current() < e0 + 32 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        stop_readers.store(true, Ordering::Relaxed);
        let mut invalidations = 0u64;
        for r in readers {
            invalidations += r.join().unwrap().invalidations;
        }
        stop_churn.store(true, Ordering::Relaxed);
        churn.join().unwrap();
        assert!(
            invalidations > 0,
            "readers never observed a shootdown — the stress ran vacuously"
        );
        // Let the daemon finish packing the quiet pool, then collect.
        // Target = the policy's idle threshold (it stops compacting
        // below score_hi, so a stricter target would burn the deadline).
        deadline = Instant::now() + Duration::from_secs(30);
        let target = ThresholdPolicy::default().score_hi;
        let mut poll = FragSampler::new();
        while poll.sample(a).score > target && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(2));
        }
        daemon.shutdown()
    });

    assert!(
        report.compact.leaves_moved > 0,
        "daemon never compacted: {}",
        report.summary()
    );
    assert_eq!(report.limbo_remaining, 0, "{}", report.summary());
    let s1 = sampler.sample(a).score;
    assert!(
        s1 * 2.0 <= s0,
        "compaction must at least halve the fragmentation score: {s0} -> {s1} ({})",
        report.summary()
    );
    assert_eq!(tree.to_vec(), mirror, "compaction churn corrupted the tree");
    registry.deregister(reg_id);
    drop(registry);
    a.epoch().synchronize(a);
    drop(tree);
    assert_eq!(a.stats().allocated, 0, "churn/compaction leaked blocks");
}

#[test]
fn daemon_compaction_stress_mutex_allocator() {
    let a = BlockAllocator::new(BLOCK, CAP).unwrap();
    compaction_stress(&a);
}

#[test]
fn daemon_compaction_stress_sharded_allocator() {
    let a = ShardedAllocator::with_shards(BLOCK, CAP, 4).unwrap();
    compaction_stress(&a);
}

/// The acceptance-criteria shape in one deterministic sweep: ≥ 2 views
/// verify checksums while the daemon compacts, final score at least
/// halved, teardown clean — via the registered experiment entry point.
#[test]
fn fragmentation_churn_experiment_end_to_end() {
    use nvm::coordinator::experiments::{fragmentation_churn, ExpConfig};
    let cfg = ExpConfig {
        sample: 25_000,
        threads: 2,
        ..ExpConfig::default()
    };
    let t = fragmentation_churn(&cfg);
    let off = t.cell("2T mmd=off", 2).expect("off row");
    let on = t.cell("2T mmd=on", 2).expect("on row");
    assert!(
        on * 2.0 <= off + 1e-9,
        "mmd must at least halve the final fragmentation score: off={off} on={on}"
    );
    assert!(t.cell("2T mmd=on", 3).unwrap() > 0.0, "no leaves moved");
}
