//! Seqlock interleaving stress: view readers + seqlock writers + a
//! migrator thread (with allocate-and-scribble block recycling) all
//! hammering one tree, under both allocator policies.
//!
//! This is `tests/concurrent_translation.rs` with the missing party
//! added — *writers*. The hazards being stressed:
//!
//! * a reader straddling a write must retry, never return a torn or
//!   half-committed value (every read asserts the slot-tag invariant);
//! * a relocation must not tear or drop a concurrent write (the copy
//!   and the write serialize on the leaf seqlock), proven by replaying
//!   every writer's seeded stream against a mirror at the end —
//!   bit-for-bit equality or the test fails;
//! * a displaced block must stay unreclaimed until every registered
//!   accessor (readers *and* writers pin the epoch) has quiesced, even
//!   while the migrator aggressively recycles and scribbles blocks.
//!
//! Run in `--release` too (CI does): the interesting interleavings
//! rarely open up at debug-build speeds.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

use nvm::pmem::{BlockAlloc, BlockAllocator, ShardedAllocator};
use nvm::testutil::Rng;
use nvm::trees::TreeArray;
use nvm::workloads::gups;

const BLOCK: usize = 1024; // u64: leaf_cap 128, fanout 128

/// `readers` tag-checking view readers + `writers` seqlock writers +
/// one migrator doing relocate/reclaim/scribble cycles. Ends by
/// replaying the writer streams onto a mirror and comparing the table.
fn rw_stress<A: BlockAlloc>(a: &A, readers: usize, writers: usize, migrations: usize) {
    let n = 128 * 24; // 24 leaves (tag invariant wants full leaves only)
    let write_ops: u64 = 30_000;
    let mut tree: TreeArray<u64, A> = TreeArray::new(a, n).unwrap();
    let mut mirror: Vec<u64> = (0..n).map(gups::rw_init).collect();
    tree.copy_from_slice(&mirror).unwrap();
    tree.enable_flat_table();
    let _ = tree.get(0); // build the flat table before sharing
    let live_before = a.stats().allocated;

    let tree = &tree;
    let stop = AtomicBool::new(false);
    let stop = &stop;
    let total_retries = AtomicU64::new(0);
    let total_retries = &total_retries;
    let wseed = |wid: usize| 0x5EED_0000 + ((wid as u64) << 8);

    std::thread::scope(|s| {
        for tid in 0..readers {
            s.spawn(move || {
                let mut view = tree.view();
                let mut reads = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    // Point reads assert the tag invariant internally...
                    std::hint::black_box(gups::gups_rw_read(
                        &mut view,
                        512,
                        0xAB00 + tid as u64 + reads,
                    ));
                    reads += 512;
                    // ...and batch reads must uphold it too.
                    let mut rng = Rng::new(0xCD00 + tid as u64 + reads);
                    let idxs: Vec<usize> = (0..64).map(|_| rng.range(0, n)).collect();
                    let got = view.get_batch(&idxs).unwrap();
                    for (k, &i) in idxs.iter().enumerate() {
                        assert_eq!(
                            got[k] >> gups::RW_TAG_SHIFT,
                            i as u64,
                            "torn batch read at slot {i} (value {:#x})",
                            got[k]
                        );
                    }
                }
                total_retries.fetch_add(view.seq_retries(), Ordering::Relaxed);
            });
        }
        let writer_handles: Vec<_> = (0..writers)
            .map(|wid| {
                s.spawn(move || {
                    // SAFETY: every concurrent accessor is a view, a
                    // seqlock writer, or the single concurrent migrator.
                    let mut w = unsafe { tree.writer() };
                    gups::gups_rw_write(&mut w, write_ops, wseed(wid))
                })
            })
            .collect();

        // Migrator (this thread): relocate under the live readers AND
        // writers, reclaim, then allocate-and-scribble — under a LIFO
        // free list the scribbled block is frequently the one a stale
        // translation would still point at.
        let mut rng = Rng::new(0x517E);
        let mut done = 0usize;
        while done < migrations || !writer_handles.iter().all(|h| h.is_finished()) {
            if done < migrations {
                let leaf = rng.range(0, tree.nleaves());
                // SAFETY: concurrent access is epoch-registered views +
                // seqlock writers; no raw slices; single migrator.
                if unsafe { tree.migrate_leaf_concurrent(leaf) }.is_ok() {
                    done += 1;
                } else {
                    a.epoch().try_reclaim(a);
                    std::thread::yield_now();
                }
            }
            a.epoch().try_reclaim(a);
            if let Ok(b) = a.alloc() {
                a.write(b, 0, &[0xA5u8; BLOCK]).unwrap();
                a.free(b).unwrap();
            }
            if done % 16 == 0 {
                std::thread::yield_now();
            }
        }
        for h in writer_handles {
            assert_eq!(h.join().unwrap(), write_ops);
        }
        stop.store(true, Ordering::Relaxed);
        assert!(done >= migrations, "migrator starved");
    });

    // Everyone is gone: limbo drains, nothing leaked.
    a.epoch().synchronize(a);
    assert_eq!(a.epoch().limbo_len(), 0);
    assert_eq!(
        a.stats().allocated,
        live_before,
        "relocation churn leaked or double-freed blocks"
    );
    // Seqlock accounting is exact: every write and every relocation
    // cycles its leaf's word by 2, so the sum over leaves must equal
    // 2 * (total writes + migrations) — a missed or double release
    // anywhere shows up here deterministically.
    let seq_sum: u64 = (0..tree.nleaves()).map(|l| tree.leaf_seq(l)).sum();
    assert_eq!(
        seq_sum,
        2 * (writers as u64 * write_ops + migrations as u64),
        "seqlock cycles do not account for every write + migration"
    );
    println!(
        "rw_stress: {} reader seq-bracket retries across {readers} readers",
        total_retries.load(Ordering::Relaxed)
    );
    // The oracle: replay every writer stream (increments commute) —
    // the table must match despite writes racing relocation the whole
    // run. A single lost or torn update diverges here.
    for wid in 0..writers {
        gups::rw_apply_reference(&mut mirror, write_ops, wseed(wid));
    }
    assert_eq!(tree.to_vec(), mirror, "writer updates lost or torn under migration churn");
}

#[test]
fn seqlock_rw_stress_mutex_allocator() {
    let a = BlockAllocator::new(BLOCK, 256).unwrap();
    rw_stress(&a, 2, 2, 300);
}

#[test]
fn seqlock_rw_stress_sharded_allocator() {
    let a = ShardedAllocator::with_shards(BLOCK, 256, 4).unwrap();
    rw_stress(&a, 2, 2, 300);
}

#[test]
fn single_writer_many_readers_stress() {
    // The bench's reader-tax shape as a correctness test: one writer,
    // 3 readers, heavier migration.
    let a = ShardedAllocator::with_shards(BLOCK, 256, 4).unwrap();
    rw_stress(&a, 3, 1, 500);
}

/// Deterministic, timing-free core of the writer/relocation handoff:
/// write, migrate, write, read — through every party — with the leaf
/// sequence observable at each step.
fn deterministic_rw_handoff<A: BlockAlloc>(a: &A) {
    let n = 128 * 4;
    let mut tree: TreeArray<u64, A> = TreeArray::new(a, n).unwrap();
    let init: Vec<u64> = (0..n).map(gups::rw_init).collect();
    tree.copy_from_slice(&init).unwrap();

    let mut view = tree.view();
    // SAFETY: accessors are the view + the writer below only.
    let mut w = unsafe { tree.writer() };
    assert_eq!(view.get(5).unwrap(), init[5]);
    assert_eq!(view.seq_retries(), 0);

    w.update(5, |v| v + 1).unwrap();
    assert_eq!(tree.leaf_seq(0), 2);
    assert_eq!(view.get(5).unwrap(), init[5] + 1, "view missed a committed write");

    // SAFETY: accessors are the epoch-registered view + seqlock writer.
    unsafe { tree.migrate_leaf_concurrent(0) }.unwrap();
    assert_eq!(tree.leaf_seq(0), 4, "relocation must cycle the seqlock");
    assert_eq!(a.epoch().try_reclaim(a), 0, "view/writer have not quiesced");

    // Post-move: both sides re-translate and agree.
    w.update(5, |v| v + 1).unwrap();
    assert_eq!(view.get(5).unwrap(), init[5] + 2, "post-move write went to the dead block");
    assert_eq!(w.get(5).unwrap(), init[5] + 2);
    assert!(a.epoch().try_reclaim(a) >= 1, "quiesced accessors must unblock reclaim");

    drop(w);
    drop(view);
    a.epoch().synchronize(a);
}

#[test]
fn deterministic_rw_handoff_mutex_allocator() {
    let a = BlockAllocator::new(BLOCK, 64).unwrap();
    deterministic_rw_handoff(&a);
}

#[test]
fn deterministic_rw_handoff_sharded_allocator() {
    let a = ShardedAllocator::with_shards(BLOCK, 64, 2).unwrap();
    deterministic_rw_handoff(&a);
}
