//! Multi-tenant isolation stress (the PR 8 acceptance suite): tenants
//! sharing one physical pool and one fault queue must not be able to
//! hurt each other. Three contracts, each driven to its edge under
//! real concurrency:
//!
//! * **Quota backpressure is scoped.** A noisy tenant that overruns its
//!   hard watermark sees typed [`Error::QuotaExceeded`] naming itself —
//!   its well-behaved neighbours churning the same pool never observe
//!   an allocation failure of any kind.
//! * **Degraded state is scoped.** A tenant whose swap backing dies
//!   takes typed [`Error::SwapFaultFailed`] and its own degraded flag;
//!   a live reader of another tenant keeps demand-faulting through the
//!   same worker-backed queue the whole time, error-free.
//! * **Data survives interference.** Every payload is checksum-verified
//!   bit-exact after the churn, and the pool returns to empty.
//!
//! CI runs this suite in `--release` as well (see TESTING.md).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::Duration;

use nvm::coordinator::experiments::{multi_tenant, ExpConfig};
use nvm::pmem::{
    BlockAlloc, BlockAllocator, BlockId, FaultQueue, FaultQueueConfig, QuotaAlloc, SwapPool,
    TenantConfig, TenantRegistry,
};
use nvm::testutil::{FailingBacking, Rng};
use nvm::trees::{CompactTarget, TreeArray};
use nvm::Error;

/// 1 KB blocks keep trees multi-leaf at test sizes (u64 leaf_cap 128).
const BLOCK: usize = 1024;
const LEAF: usize = 128;

/// Two well-behaved tenants and one noisy tenant churn one pool from
/// six threads. The noisy pair's combined appetite (2 × 8 blocks)
/// exceeds its hard watermark (10), so it must keep hitting typed
/// [`Error::QuotaExceeded`]; the pool itself never runs dry (total hard
/// quotas are well under capacity), so any error observed by a
/// well-behaved tenant — quota or OOM — fails the test. Every held
/// block carries a tenant-tagged payload verified on free.
#[test]
fn quota_backpressure_is_per_tenant_under_concurrent_churn() {
    let a = BlockAllocator::new(BLOCK, 256).unwrap();
    let reg = TenantRegistry::new();
    let good = [
        reg.admit(TenantConfig::new(48, 64)),
        reg.admit(TenantConfig::new(48, 64)),
    ];
    let noisy = reg.admit(TenantConfig::new(6, 10));
    let quota_hits = AtomicU64::new(0);

    std::thread::scope(|s| {
        for (ti, t) in good.iter().enumerate() {
            for th in 0..2u64 {
                let qa = QuotaAlloc::new(&a, t.clone());
                s.spawn(move || {
                    let mut rng = Rng::new(0xC0FFEE ^ ((ti as u64) << 8) ^ th);
                    let mut held: Vec<(BlockId, u64)> = Vec::new();
                    for i in 0..1500u64 {
                        // Each thread holds at most 20 blocks, so the
                        // tenant peaks at 40 < its hard quota of 64.
                        if held.len() < 20 && (held.is_empty() || rng.chance(0.6)) {
                            let b = qa.alloc().unwrap_or_else(|e| {
                                panic!(
                                    "well-behaved tenant {} must never see an \
                                     allocation failure: {e:?}",
                                    qa.tenant().id()
                                )
                            });
                            let tag = ((qa.tenant().id() as u64) << 48) ^ (b.0 as u64) << 8 ^ i;
                            qa.write(b, 0, &tag.to_le_bytes()).unwrap();
                            held.push((b, tag));
                        } else {
                            let k = rng.below(held.len() as u64) as usize;
                            let (b, tag) = held.swap_remove(k);
                            let mut buf = [0u8; 8];
                            qa.read(b, 0, &mut buf).unwrap();
                            assert_eq!(
                                u64::from_le_bytes(buf),
                                tag,
                                "tenant payload scribbled by a neighbour"
                            );
                            qa.free(b).unwrap();
                        }
                    }
                    for (b, tag) in held {
                        let mut buf = [0u8; 8];
                        qa.read(b, 0, &mut buf).unwrap();
                        assert_eq!(u64::from_le_bytes(buf), tag);
                        qa.free(b).unwrap();
                    }
                });
            }
        }
        for th in 0..2u64 {
            let qa = QuotaAlloc::new(&a, noisy.clone());
            let hits = &quota_hits;
            s.spawn(move || {
                let mut rng = Rng::new(0xBAD ^ th);
                let mut held: Vec<BlockId> = Vec::new();
                for _ in 0..1500 {
                    if held.len() < 8 && (held.is_empty() || rng.chance(0.7)) {
                        match qa.alloc() {
                            Ok(b) => held.push(b),
                            Err(Error::QuotaExceeded { tenant, used, quota }) => {
                                assert_eq!(tenant, qa.tenant().id());
                                assert_eq!(quota, 10);
                                assert!(used <= quota, "charge must roll back: {used} > {quota}");
                                hits.fetch_add(1, Ordering::Relaxed);
                                if let Some(b) = held.pop() {
                                    qa.free(b).unwrap();
                                }
                            }
                            Err(other) => {
                                panic!("noisy overrun must be QuotaExceeded, got {other:?}")
                            }
                        }
                    } else if let Some(b) = held.pop() {
                        qa.free(b).unwrap();
                    }
                }
                for b in held {
                    qa.free(b).unwrap();
                }
            });
        }
    });

    assert!(
        quota_hits.load(Ordering::Relaxed) > 0,
        "the noisy pair never hit its hard watermark — the test lost its teeth"
    );
    assert_eq!(noisy.quota_failures(), quota_hits.load(Ordering::Relaxed));
    assert_eq!(noisy.used(), 0);
    for t in &good {
        assert_eq!(t.quota_failures(), 0, "backpressure leaked across tenants");
        assert_eq!(t.used(), 0);
    }
    assert_eq!(a.stats().allocated, 0, "churn must return the pool to empty");
}

/// One worker-backed fault queue, two tenants with routed backings. The
/// second tenant's backing is killed and revived repeatedly while a
/// live reader of the first tenant demand-faults through the same queue
/// the whole time. Every outage must degrade tenant 2 alone (queue flag
/// and registry mirror), surface as typed [`Error::SwapFaultFailed`] to
/// tenant 2's accessor only, and clear on the first success after
/// recovery; both payloads end bit-exact.
#[test]
fn dead_backing_degrades_only_its_tenant_under_live_readers() {
    let a = BlockAllocator::new(BLOCK, 96).unwrap();
    let tenants = TenantRegistry::new();
    let t1 = tenants.admit(TenantConfig::new(64, 96));
    let t2 = tenants.admit(TenantConfig::new(64, 96));
    let swap1 = SwapPool::anonymous(&a).unwrap();
    let (fb, ctl) = FailingBacking::new();
    let swap2 = SwapPool::with_backing(&a, fb);
    let q = FaultQueue::with_tenants(
        &swap1,
        FaultQueueConfig {
            max_depth: 16,
            max_retries: 3,
            backoff_base: Duration::from_micros(50),
            backoff_cap: Duration::from_micros(200),
            ..FaultQueueConfig::default()
        },
        &tenants,
    );
    q.route_tenant(t2.id(), &swap2);

    let nleaves = 8;
    let len = LEAF * nleaves;
    let mut tree1: TreeArray<u64> = TreeArray::new(&a, len).unwrap();
    let mut tree2: TreeArray<u64> = TreeArray::new(&a, len).unwrap();
    let d1: Vec<u64> = (0..len).map(|i| (i as u64).wrapping_mul(13) | 1).collect();
    let d2: Vec<u64> = (0..len).map(|i| (i as u64).wrapping_mul(29) | 1).collect();
    tree1.copy_from_slice(&d1).unwrap();
    tree2.copy_from_slice(&d2).unwrap();
    let f1 = q.scoped(t1.id());
    let f2 = q.scoped(t2.id());
    // SAFETY: cleared below before the scoped faulters drop.
    unsafe { tree1.install_faulter(&f1) };
    unsafe { tree2.install_faulter(&f2) };

    let stop = AtomicBool::new(false);
    let reads = AtomicU64::new(0);
    let outages = 6usize;
    std::thread::scope(|s| {
        q.attach_workers(s, 2);
        let (tree1_r, d1_r, stop_r, reads_r) = (&tree1, &d1, &stop, &reads);
        let reader = s.spawn(move || {
            let mut v = tree1_r.view();
            let mut rng = Rng::new(0x7EA);
            while !stop_r.load(Ordering::Acquire) {
                let i = rng.below(len as u64) as usize;
                match v.get(i) {
                    Ok(x) => assert_eq!(x, d1_r[i], "healthy tenant read corrupted at {i}"),
                    Err(e) => panic!("healthy tenant must never see a fault error: {e:?}"),
                }
                reads_r.fetch_add(1, Ordering::Relaxed);
            }
            v.faults()
        });

        let mut v2 = tree2.view();
        for round in 0..outages {
            // Keep the healthy tenant taking real demand faults through
            // the shared queue for the duration of every outage.
            for leaf in 0..nleaves {
                if leaf % 2 == round % 2 && CompactTarget::leaf_swap_slot(&tree1, leaf).is_none() {
                    // SAFETY: the only accessors are fault-capable views.
                    unsafe { CompactTarget::evict_leaf(&tree1, leaf, &f1) }.unwrap();
                }
            }
            // Park one t2 leaf while its backing is healthy, then kill
            // the backing: the demand fault burns the retry budget and
            // must surface typed — on this tenant only.
            let leaf = round % nleaves;
            if CompactTarget::leaf_swap_slot(&tree2, leaf).is_none() {
                // SAFETY: as above.
                unsafe { CompactTarget::evict_leaf(&tree2, leaf, &f2) }.unwrap();
            }
            ctl.fail_always();
            match v2.get(leaf * LEAF) {
                Err(Error::SwapFaultFailed { .. }) => {}
                other => panic!("want SwapFaultFailed on the dead backing, got {other:?}"),
            }
            assert!(q.degraded_for(t2.id()));
            assert!(t2.degraded(), "registry must mirror the queue's verdict");
            assert!(!q.degraded_for(t1.id()), "degradation leaked across tenants");
            assert!(!t1.degraded());
            // Recovery: the same access succeeds and clears the flag.
            ctl.disarm();
            assert_eq!(v2.get(leaf * LEAF).unwrap(), d2[leaf * LEAF]);
            assert!(!q.degraded_for(t2.id()), "first success must clear the flag");
            assert!(!t2.degraded());
        }
        drop(v2);
        stop.store(true, Ordering::Release);
        let reader_faults = reader.join().unwrap();
        assert!(
            reader_faults > 0,
            "the healthy tenant never demand-faulted — the outages ran unopposed"
        );
        q.shutdown_workers();
    });

    // Drain whatever is still parked (restore is a no-op on resident
    // leaves) and verify both payloads survived the interference.
    for leaf in 0..nleaves {
        CompactTarget::restore_leaf(&tree1, leaf, &f1).unwrap();
        CompactTarget::restore_leaf(&tree2, leaf, &f2).unwrap();
    }
    assert!(reads.load(Ordering::Relaxed) > 0);
    let st = q.stats();
    assert!(st.permanent >= outages as u64, "every outage escalates once: {st:?}");
    assert!(t1.snapshot().faults > 0 && t2.snapshot().faults > 0);
    assert_eq!(tree1.to_vec(), d1, "healthy tenant data lost to a neighbour's outage");
    assert_eq!(tree2.to_vec(), d2, "parked payloads must survive the outage bit-exact");
    tree1.clear_faulter();
    tree2.clear_faulter();
    a.epoch().synchronize(&a);
    drop((tree1, tree2));
    drop((swap1, swap2));
    assert_eq!(a.stats().allocated, 0);
}

/// The `multi-tenant` experiment end-to-end at a quick sample: five
/// tenants (zipfian / scan / insert+churn / noisy over-quota /
/// flaky-backing) share one pool, one fault queue, and one daemon. The
/// run function carries its own containment and bit-exactness
/// assertions, so this is the tentpole's whole acceptance contract in
/// one call; the spot checks below only pin the table's shape.
#[test]
fn multi_tenant_experiment_end_to_end() {
    let cfg = ExpConfig {
        sample: 20_000,
        threads: 2,
        ..Default::default()
    };
    let t = multi_tenant(&cfg);
    assert!(t.cell("zipfian", 0).expect("zipfian row present") > 0.0);
    assert!(t.cell("scan", 0).expect("scan row present") > 0.0);
    assert!(
        t.cell("noisy", 3).expect("noisy row present") > 0.0,
        "the noisy tenant must have been backpressured"
    );
    assert!(
        t.cell("flaky", 4).expect("flaky row present") > 0.0,
        "the flaky tenant must have seen typed fault errors"
    );
}
