//! Integration: cross-module system behaviour without the PJRT runtime
//! (allocator ↔ trees ↔ stack ↔ workloads ↔ experiments).

use nvm::coordinator::experiments::{self, ExpConfig};
use nvm::coordinator::run_experiment;
use nvm::memsim::{AddressMode, Hierarchy, PageSize};
use nvm::pmem::{BlockAlloc, BlockAllocator, ShardedAllocator};
use nvm::stack::SplitStack;
use nvm::testutil::Rng;
use nvm::trees::TreeArray;
use nvm::workloads::{blackscholes as bs, gups, hashprobe, linear_scan};

fn tiny_cfg() -> ExpConfig {
    ExpConfig {
        sample: 30_000,
        threads: 4,
        ..ExpConfig::default()
    }
}

#[test]
fn all_experiments_dispatch_and_produce_tables() {
    for name in [
        "table2",
        "fig3",
        "fig4-gups",
        "fig5",
        "concurrent-gups",
        "concurrent-probe",
        "concurrent-rw",
        "fragmentation-churn",
        "parallel-blackscholes",
        "batched-workloads",
        "ablation-alloc",
        "ablation-block-size",
        "ablation-ptw",
    ] {
        let tables = run_experiment(name, &tiny_cfg()).unwrap_or_else(|e| {
            panic!("{name} failed: {e}");
        });
        assert!(!tables.is_empty());
        for t in &tables {
            assert!(!t.rows.is_empty(), "{name}: empty table");
            let md = t.to_markdown();
            assert!(md.starts_with("###"), "{name}: bad markdown");
        }
    }
}

#[test]
fn fig4_rbtree_small() {
    // The rbtree experiment with a reduced size set (full sizes run in
    // the bench).
    let cfg = tiny_cfg();
    let t = experiments::fig4_rbtree(&cfg);
    for c in 0..2 {
        let v = t.cell("rbtree insert+traverse", c).unwrap();
        assert!(
            (0.2..1.0).contains(&v),
            "physical/virtual rbtree ratio {v} out of the paper's winning range"
        );
    }
}

#[test]
fn shared_allocator_hosts_everything_at_once() {
    // One pool backing arrays, a stack, and workload tables concurrently
    // — the "general-purpose OS allocator" story of §3.
    let alloc = BlockAllocator::with_capacity_bytes(96 << 20).unwrap();
    let mut rng = Rng::new(8);

    let data: Vec<f32> = (0..1 << 20).map(|_| rng.f32_range(0.0, 1.0)).collect();
    let arr = linear_scan::tree_from(&alloc, &data);

    let mut stack = SplitStack::new(&alloc).unwrap();
    for d in 0..10_000u64 {
        stack.call(200, &d.to_le_bytes()).unwrap();
    }

    let mut table: TreeArray<u64> = TreeArray::new(&alloc, 1 << 18).unwrap();
    let checksum = gups::gups_tree_naive(&mut table, 100_000, 9);

    // Everything still correct while coexisting.
    assert_eq!(linear_scan::scan_tree_iter(&arr), linear_scan::scan_vec(&data));
    assert!(checksum != 0);
    assert!(alloc.stats().allocated > 0);

    while stack.depth() > 0 {
        stack.ret().unwrap();
    }
    drop(stack);
    drop(arr);
    drop(table);
    assert_eq!(alloc.stats().allocated, 0, "all subsystems must release blocks");
}

#[test]
fn sharded_allocator_hosts_everything_at_once() {
    // The same §3 "one pool backs everything" story, through the trait:
    // arrays, a split stack, and a GUPS table share one sharded pool.
    let alloc = ShardedAllocator::with_capacity_bytes(96 << 20).unwrap();
    let mut rng = Rng::new(8);

    let data: Vec<f32> = (0..1 << 18).map(|_| rng.f32_range(0.0, 1.0)).collect();
    let arr = linear_scan::tree_from(&alloc, &data);

    let mut stack = SplitStack::new(&alloc).unwrap();
    for d in 0..5_000u64 {
        stack.call(200, &d.to_le_bytes()).unwrap();
    }

    let mut table: TreeArray<u64, ShardedAllocator> = TreeArray::new(&alloc, 1 << 16).unwrap();
    let checksum = gups::gups_tree_naive(&mut table, 50_000, 9);

    assert_eq!(linear_scan::scan_tree_iter(&arr), linear_scan::scan_vec(&data));
    assert!(checksum != 0);
    assert!(alloc.stats().allocated > 0);

    while stack.depth() > 0 {
        stack.ret().unwrap();
    }
    drop(stack);
    drop(arr);
    drop(table);
    assert_eq!(alloc.stats().allocated, 0, "all subsystems must release blocks");
}

#[test]
fn mixed_allocators_coexist() {
    // Generic consumers accept either policy in the same process; data
    // round-trips identically.
    let mutex = BlockAllocator::new(4096, 512).unwrap();
    let sharded = ShardedAllocator::with_shards(4096, 512, 4).unwrap();
    let data: Vec<f32> = (0..10_000).map(|i| i as f32).collect();
    let t1 = linear_scan::tree_from(&mutex, &data);
    let t2 = linear_scan::tree_from(&sharded, &data);
    assert_eq!(linear_scan::scan_tree_iter(&t1), linear_scan::scan_tree_iter(&t2));
    assert_eq!(t1.to_vec(), t2.to_vec());
}

#[test]
fn allocator_exhaustion_surfaces_cleanly_through_trees() {
    let alloc = BlockAllocator::new(32 * 1024, 8).unwrap();
    // 8 blocks cannot host a 1M-element tree; error, not panic/leak.
    let r: Result<TreeArray<f32>, _> = TreeArray::new(&alloc, 1 << 20);
    assert!(r.is_err());
    assert_eq!(alloc.stats().allocated, 0);
    // And the pool is still fully usable afterwards.
    let ok: TreeArray<f32> = TreeArray::new(&alloc, 1000).unwrap();
    assert_eq!(ok.depth(), 1);
}

#[test]
fn real_blackscholes_layouts_agree_at_scale() {
    let n = (1 << 20) + 77;
    let alloc = BlockAllocator::with_capacity_bytes(n * 4 * 6 + (16 << 20)).unwrap();
    let (s, k, t) = bs::synth_portfolio(n, 12);
    let mut call_c = vec![0.0f32; n];
    let mut put_c = vec![0.0f32; n];
    bs::price_contig(&s, &k, &t, 0.03, 0.25, &mut call_c, &mut put_c);

    let mut ts: TreeArray<f32> = TreeArray::new(&alloc, n).unwrap();
    let mut tk: TreeArray<f32> = TreeArray::new(&alloc, n).unwrap();
    let mut tt: TreeArray<f32> = TreeArray::new(&alloc, n).unwrap();
    ts.copy_from_slice(&s).unwrap();
    tk.copy_from_slice(&k).unwrap();
    tt.copy_from_slice(&t).unwrap();
    let mut tc: TreeArray<f32> = TreeArray::new(&alloc, n).unwrap();
    let mut tp: TreeArray<f32> = TreeArray::new(&alloc, n).unwrap();
    bs::price_tree_iter(&ts, &tk, &tt, 0.03, 0.25, &mut tc, &mut tp);
    assert_eq!(tc.to_vec(), call_c);
    assert_eq!(tp.to_vec(), put_c);
}

#[test]
fn hugepage_artifact_mechanism() {
    // §4.3: beyond ~16 GB, 1 GB-page simulation stops being faithful
    // because 1 GB TLB entries run out. Verify the mechanism end to end
    // through the probe workload.
    let model = nvm::workloads::CostModel::default();
    let mut h_phys = Hierarchy::kaby_lake(AddressMode::Physical);
    let mut h_huge = Hierarchy::kaby_lake(AddressMode::Virtual(PageSize::P1G));
    let bytes = 32u64 << 30;
    let p = hashprobe::sim_probe(&mut h_phys, &model, bytes, true, 100_000, 3);
    let g = hashprobe::sim_probe(&mut h_huge, &model, bytes, true, 100_000, 3);
    assert!(
        g.cycles_per_elem > p.cycles_per_elem,
        "huge-page sim ({:.1}) must cost more than true physical ({:.1}) at 32 GB",
        g.cycles_per_elem,
        p.cycles_per_elem
    );
    // Each tree access = 3 loads (root, interior, leaf); root/interior
    // pages stay TLB-resident, so only the leaf load misses: ~1/3.
    assert!(
        g.tlb_miss_rate > 0.25,
        "1G TLB should thrash on leaf loads at 32 GB (got {:.3})",
        g.tlb_miss_rate
    );
}
