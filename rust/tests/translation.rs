//! Integration tests for the software translation-cache subsystem:
//! cursor/TLB invalidation under leaf relocation (the stale-pointer
//! hazard), flat-table mode, and batched access — each run against both
//! allocator policies.
//!
//! The scenario that motivated generation-based shootdown: a `Cursor`
//! caches a leaf pointer, a `Relocator`-style migration moves the leaf
//! and frees the old block, the allocator recycles that block to a new
//! owner, and the cursor — without revalidation — would silently read
//! the new owner's bytes. These tests allocate-and-scribble after the
//! migration to make that corruption observable if it ever regresses.

use nvm::pmem::{BlockAlloc, BlockAllocator, ShardedAllocator};
use nvm::testutil::Rng;
use nvm::trees::TreeArray;
use nvm::workloads::gups;

const BLOCK: usize = 1024; // u32: leaf_cap 256, fanout 128

fn filled_tree<A: BlockAlloc>(a: &A, n: usize) -> (TreeArray<'_, u32, A>, Vec<u32>) {
    let mut t: TreeArray<u32, A> = TreeArray::new(a, n).expect("tree");
    let data: Vec<u32> = (0..n as u32).map(|i| i.wrapping_mul(2246822519)).collect();
    t.copy_from_slice(&data).expect("fill");
    (t, data)
}

/// The stale-cursor-after-relocate scenario, generic over the allocator.
fn stale_cursor_case<A: BlockAlloc>(a: &A) {
    let n = 256 * 4;
    let (t, data) = filled_tree(a, n);
    let mut c = t.cursor();
    assert_eq!(c.seek(10), data[10]); // cursor now caches leaf 0
    let (_, walks_before) = c.cache_stats();

    let gen0 = t.generation();
    // SAFETY: only the cursor (which revalidates) observes the tree; no
    // leaf slices are live.
    let fresh = unsafe { t.migrate_leaf_shared(0) }.expect("migrate");
    assert_eq!(t.generation(), gen0 + 1, "relocation must bump the generation");

    // The freed block goes back to the pool; hand it to a "new owner"
    // and scribble. Under the LIFO BlockAllocator this is *exactly* the
    // block the cursor still points at — the silent-corruption window.
    let recycled = a.alloc().expect("recycle");
    a.write(recycled, 0, &[0xA5u8; BLOCK]).expect("scribble");

    // A revalidating cursor re-walks to the fresh block and reads the
    // original data; a stale one reads 0xA5A5A5A5.
    assert_eq!(c.seek(10), data[10], "cursor read the recycled block");
    let (_, walks_after) = c.cache_stats();
    assert!(walks_after > walks_before, "revalidation must re-walk");

    // And the cursor tracks the *fresh* location: write a marker there
    // directly and the cursor must see it.
    let marker = 0xFEED_FACEu32;
    a.write(fresh, 10 * 4, &marker.to_le_bytes()).expect("marker");
    assert_eq!(c.seek(10), marker, "cursor not following the relocated leaf");

    // Untouched leaves unaffected.
    assert_eq!(c.seek(700), data[700]);
    a.free(recycled).expect("cleanup");
}

#[test]
fn stale_cursor_after_relocate_mutex_allocator() {
    let a = BlockAllocator::new(BLOCK, 256).unwrap();
    stale_cursor_case(&a);
}

#[test]
fn stale_cursor_after_relocate_sharded_allocator() {
    let a = ShardedAllocator::with_shards(BLOCK, 256, 4).unwrap();
    stale_cursor_case(&a);
}

/// TLB entries (not just the current leaf) must also revalidate: cache a
/// leaf in the TLB, relocate it, and the next access must invalidate
/// rather than hit.
fn tlb_shootdown_case<A: BlockAlloc>(a: &A) {
    let n = 256 * 4;
    let (t, data) = filled_tree(a, n);
    let mut c = t.cursor();
    assert_eq!(c.seek(10), data[10]); // leaf 0: walk, TLB fill
    assert_eq!(c.seek(300), data[300]); // leaf 1: walk, TLB fill
    assert_eq!(c.seek(20), data[20]); // leaf 0 revisit: TLB hit
    assert_eq!(c.tlb_stats().hits, 1);
    assert_eq!(c.tlb_stats().invalidations, 0);

    // SAFETY: only the revalidating cursor observes the tree.
    unsafe { t.migrate_leaf_shared(0) }.expect("migrate");
    let recycled = a.alloc().expect("recycle");
    a.write(recycled, 0, &[0x5Au8; BLOCK]).expect("scribble");

    assert_eq!(c.seek(30), data[30], "TLB served a dead translation");
    assert!(
        c.tlb_stats().invalidations >= 1,
        "stale TLB entry must be invalidated, got {:?}",
        c.tlb_stats()
    );
    a.free(recycled).expect("cleanup");
}

#[test]
fn tlb_shootdown_mutex_allocator() {
    let a = BlockAllocator::new(BLOCK, 256).unwrap();
    tlb_shootdown_case(&a);
}

#[test]
fn tlb_shootdown_sharded_allocator() {
    let a = ShardedAllocator::with_shards(BLOCK, 256, 4).unwrap();
    tlb_shootdown_case(&a);
}

/// A sequential iteration that straddles a migration must still produce
/// the original values (the iterator revalidates at leaf boundaries and
/// within leaves via the generation check).
#[test]
fn iteration_straddling_migration_stays_correct() {
    let a = BlockAllocator::new(BLOCK, 256).unwrap();
    let n = 256 * 6;
    let (t, data) = filled_tree(&a, n);
    let mut c = t.iter();
    let mut got = Vec::with_capacity(n);
    for _ in 0..n / 2 {
        got.push(c.next().unwrap());
    }
    // Move both a visited and a not-yet-visited leaf mid-iteration.
    // SAFETY: only the revalidating iterator observes the tree.
    unsafe { t.migrate_leaf_shared(0) }.expect("migrate visited");
    unsafe { t.migrate_leaf_shared(5) }.expect("migrate upcoming");
    for v in c {
        got.push(v);
    }
    assert_eq!(got, data);
}

/// Flat-table mode over both allocators, across relocation.
fn flat_mode_case<A: BlockAlloc>(a: &A) {
    let n = 256 * 8 + 17;
    let (mut t, data) = filled_tree(a, n);
    t.enable_flat_table();
    let mut rng = Rng::new(9);
    for _ in 0..400 {
        let i = rng.range(0, n);
        assert_eq!(t.get(i).unwrap(), data[i]);
    }
    for leaf in 0..t.nleaves() {
        t.migrate_leaf(leaf).expect("migrate");
    }
    for _ in 0..400 {
        let i = rng.range(0, n);
        assert_eq!(t.get(i).unwrap(), data[i], "flat table stale after relocation");
    }
    assert_eq!(t.to_vec(), data);
}

#[test]
fn flat_table_mode_mutex_allocator() {
    let a = BlockAllocator::new(BLOCK, 256).unwrap();
    flat_mode_case(&a);
}

#[test]
fn flat_table_mode_sharded_allocator() {
    let a = ShardedAllocator::with_shards(BLOCK, 256, 4).unwrap();
    flat_mode_case(&a);
}

/// Batched GUPS over the sharded allocator matches the contiguous-table
/// reference bit for bit (the unit tests cover the mutex allocator).
#[test]
fn batched_gups_matches_vec_under_sharded_allocator() {
    let a = ShardedAllocator::with_shards(4096, 1024, 4).unwrap();
    let n = 1 << 13;
    let mut vec_table = vec![0u64; n];
    let c1 = gups::gups_vec(&mut vec_table, 40_000, 17);
    let mut tree_table: TreeArray<u64, ShardedAllocator> = TreeArray::new(&a, n).unwrap();
    let c2 = gups::gups_tree_batched(&mut tree_table, 40_000, 17, 256);
    assert_eq!(c1, c2);
    assert_eq!(tree_table.to_vec(), vec_table);
}

/// Relocation must not leak blocks and the pool must drain fully when
/// trees drop, with live cursors having revalidated along the way.
#[test]
fn no_leaks_after_heavy_relocation_with_live_cursor() {
    let a = BlockAllocator::new(BLOCK, 1024).unwrap();
    {
        let n = 256 * 10;
        let (t, data) = filled_tree(&a, n);
        let live = a.stats().allocated;
        let mut c = t.cursor();
        let mut rng = Rng::new(31);
        for round in 0..50 {
            let leaf = rng.range(0, t.nleaves());
            // SAFETY: only the revalidating cursor observes the tree.
            unsafe { t.migrate_leaf_shared(leaf) }.expect("migrate");
            let i = rng.range(0, n);
            assert_eq!(c.seek(i), data[i], "round {round}, elem {i}");
        }
        assert_eq!(a.stats().allocated, live, "relocation churn leaked blocks");
    }
    assert_eq!(a.stats().allocated, 0);
}
